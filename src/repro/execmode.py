"""Execution-mode resolution for the katana Pallas kernels.

Every kernel op used to default to ``interpret=True`` — correct on this
CPU container, but it let "interpret" leak into benchmark numbers
without being recorded, so dispatch-count wins measured through the
Pallas interpreter were indistinguishable from compiled-kernel wins.
This module is the single place that decision is made:

  * ``KATANA_MODE`` env (``auto`` / ``interpret`` / ``compiled``) or an
    explicit per-call / per-``TrackerConfig`` request selects the mode;
  * ``pallas_lowering_supported()`` probes (once, cached) whether the
    active jax backend can actually lower a ``pallas_call`` with
    ``interpret=False`` — CPU backends up to current jax cannot;
  * a ``compiled`` request on a backend that can't lower falls back to
    the interpreter LOUDLY: a ``ExecModeFallbackWarning`` at resolve
    time plus a non-None ``ExecMode.fallback`` reason that benchmark
    rows and the CI compiled-mode job assert on. Interpreted execution
    can never silently masquerade as compiled.

The resolved ``ExecMode`` also names the backend and jax version so
every BENCH_*.json row can record how its code actually executed:
``lowering="pallas-interpret"`` (kernel through the interpreter),
``"pallas"`` (natively compiled kernel), or ``"xla"`` (the XLA-native
einsum/lanes formulation — real compiled code on every backend,
including CPU).
"""
from __future__ import annotations

import functools
import os
import warnings
from dataclasses import dataclass
from typing import Optional

ENV_VAR = "KATANA_MODE"
MODES = ("auto", "interpret", "compiled")


class ExecModeFallbackWarning(UserWarning):
    """A requested ``compiled`` execution is running interpreted because
    the backend cannot lower Pallas — loud by design."""


@dataclass(frozen=True)
class ExecMode:
    requested: str        # what the caller/env asked for
    mode: str             # what actually runs: "interpret" | "compiled"
    backend: str          # jax.default_backend()
    pallas_native: bool   # backend can lower pallas_call(interpret=False)
    fallback: Optional[str]  # non-None iff compiled was requested but
    #                          the kernels run interpreted
    jax_version: str

    @property
    def interpret(self) -> bool:
        """What the kernel ops pass to ``pallas_call``."""
        return self.mode == "interpret"

    def lowering(self, pallas: bool = True) -> str:
        """How a code path executes under this mode: ``"xla"`` for the
        einsum/lanes formulations (native compiled code everywhere),
        ``"pallas"`` / ``"pallas-interpret"`` for kernel dispatches."""
        if not pallas:
            return "xla"
        return "pallas" if self.mode == "compiled" else "pallas-interpret"

    def row_mode(self, pallas: bool = True) -> str:
        """The honest per-BENCH-row mode label: XLA-native paths are
        compiled code on every backend; Pallas paths are compiled only
        when the kernel itself lowered natively."""
        return "interpret" if self.lowering(pallas) == "pallas-interpret" \
            else "compiled"

    def as_meta(self) -> dict:
        """Top-of-file metadata for BENCH_*.json."""
        return dict(requested=self.requested, mode=self.mode,
                    backend=self.backend, pallas_native=self.pallas_native,
                    fallback=self.fallback, jax=self.jax_version)


@functools.lru_cache(maxsize=None)
def pallas_lowering_supported(backend: Optional[str] = None) -> bool:
    """Probe (once per backend) whether ``pallas_call(interpret=False)``
    lowers on this jax backend. CPU raises ``Only interpret mode is
    supported on CPU backend`` up to current jax; TPU/GPU lower."""
    import jax
    from jax.experimental import pallas as pl

    def _kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] + 1.0

    try:
        import jax.numpy as jnp
        x = jnp.zeros((8, 128), jnp.float32)
        jax.jit(lambda x: pl.pallas_call(
            _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=False)(x)).lower(x)
        return True
    except Exception:  # noqa: BLE001 — any lowering failure means "no"
        return False


@functools.lru_cache(maxsize=None)
def _resolve(requested: str, backend: str, jax_version: str) -> ExecMode:
    native = pallas_lowering_supported(backend)
    fallback = None
    if requested == "auto":
        mode = "compiled" if native else "interpret"
    elif requested == "interpret":
        mode = "interpret"
    else:  # compiled
        if native:
            mode = "compiled"
        else:
            mode = "interpret"
            fallback = f"pallas-lowering-unsupported:{backend}"
            warnings.warn(
                f"KATANA_MODE=compiled requested but the {backend!r} jax "
                f"backend cannot lower Pallas kernels — kernel dispatches "
                f"fall back to the interpreter (XLA-native einsum/lanes "
                f"paths still run compiled). Benchmark rows record this "
                f"as fallback={fallback!r}.",
                ExecModeFallbackWarning, stacklevel=3)
    return ExecMode(requested=requested, mode=mode, backend=backend,
                    pallas_native=native, fallback=fallback,
                    jax_version=jax_version)


def resolve_mode(requested: Optional[str] = None) -> ExecMode:
    """Resolve the execution mode: explicit ``requested`` wins, else the
    ``KATANA_MODE`` env var, else ``auto`` (compiled where the backend
    can lower Pallas, interpret elsewhere)."""
    import jax

    if requested is None:
        requested = os.environ.get(ENV_VAR, "auto").strip().lower() or "auto"
    if requested not in MODES:
        raise ValueError(
            f"{ENV_VAR}={requested!r}: expected one of {MODES}")
    return _resolve(requested, jax.default_backend(), jax.__version__)


def active_mode() -> ExecMode:
    """The environment-resolved mode (what ops use when no explicit
    ``interpret=``/``mode=`` is passed)."""
    return resolve_mode(None)


def resolve_interpret(interpret: Optional[bool] = None,
                      mode: Optional[str] = None) -> bool:
    """The ops-level shim: an explicit ``interpret=`` always wins
    (tests pin the interpreter); otherwise the resolved mode decides."""
    if interpret is not None:
        return bool(interpret)
    return resolve_mode(mode).interpret
