"""Thin jax version-compat layer.

The repo targets current jax but must degrade gracefully on the older
runtime baked into CI/containers (0.4.x): ``jax.shard_map``,
``jax.make_mesh`` and ``jax.sharding.AxisType`` only exist on newer
releases, and the old shard_map spelling lives under
``jax.experimental.shard_map`` with ``check_rep`` instead of
``check_vma``. Keep every such switch here so call sites stay clean.
"""
from __future__ import annotations

import jax


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis, from inside shard_map/pmap."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    frame = jax.core.axis_frame(axis)  # int on 0.4.x, frame on some builds
    return getattr(frame, "size", frame)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map (check=False disables the rep/vma
    static checker on either API)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


def make_mesh(shape, axes, **kwargs):
    """Version-portable ``jax.make_mesh`` (added in 0.4.35): older
    releases fall back to ``mesh_utils.create_device_mesh`` + ``Mesh``.
    Extra kwargs (``axis_types``) are dropped on the fallback — the old
    Mesh has no axis-type concept. Like ``jax.make_mesh``, a mesh
    smaller than the host uses the first prod(shape) devices
    (``create_device_mesh`` alone would demand an exact count)."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
    import math

    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    devs = jax.devices()[:math.prod(shape)]
    return Mesh(mesh_utils.create_device_mesh(tuple(shape), devs),
                tuple(axes))
