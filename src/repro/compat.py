"""Thin jax version-compat layer.

The repo targets current jax but must degrade gracefully on the older
runtime baked into CI/containers (0.4.x): ``jax.shard_map`` and
``jax.sharding.AxisType`` only exist on newer releases, and the old
spelling lives under ``jax.experimental.shard_map`` with ``check_rep``
instead of ``check_vma``. Keep every such switch here so call sites
stay clean.
"""
from __future__ import annotations

import jax


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis, from inside shard_map/pmap."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    frame = jax.core.axis_frame(axis)  # int on 0.4.x, frame on some builds
    return getattr(frame, "size", frame)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map (check=False disables the rep/vma
    static checker on either API)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
