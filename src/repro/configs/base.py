"""Config system: model architecture + workload shapes + run settings.

Every assigned architecture is a ``ModelConfig`` constant in its own
module under ``repro.configs``; the registry in ``__init__`` resolves
``--arch <id>`` strings. Shape cells (train_4k / prefill_32k / decode_32k
/ long_500k) are ``ShapeConfig``s; ``cells_for(arch)`` yields the
well-defined (arch x shape) cells, honouring the skip rules recorded in
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # Static capacity factor: tokens routed per expert per batch are
    # bounded (KATANA Opt-2 discipline: no dynamic shapes anywhere).
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # every `period` layers one MoE layer (1 = every layer is MoE)
    period: int = 1


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length
    # d_inner = expand * d_model; n_heads = d_inner // head_dim


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    sliding_window: Optional[int] = None  # SWA width (h2o-danube)
    rope_theta: float = 10000.0
    use_rope: bool = True  # False => learned absolute positions
    qkv_bias: bool = False
    softmax_scale: Optional[float] = None


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    d_ff: int  # dense FFN width (0 for attn-free / pure-MoE archs)
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: one attention layer every `attn_period` layers,
    # remaining layers are SSM (jamba: 1:7).
    attn_period: int = 1
    act: str = "swiglu"  # swiglu | squared_relu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    bidirectional: bool = False  # encoder-only (hubert)
    is_encoder_only: bool = False
    # modality frontend stubs (vlm/audio): inputs are precomputed
    # frame/patch embeddings of this many positions, prepended/replacing
    # token inputs. None => pure token LM.
    frontend: Optional[str] = None  # "vision" | "audio"
    frontend_positions: int = 0
    dtype: str = "bfloat16"
    # citation tier from the assignment table
    source: str = ""

    @property
    def d_head_total(self) -> int:
        a = self.attention
        return a.n_heads * a.head_dim if a else 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string ('attn'|'ssm') honouring attn_period."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm",):
                kinds.append("ssm")
            elif self.family == "hybrid":
                # jamba: attention at positions p-1, 2p-1, ... (1 in p)
                kinds.append(
                    "attn" if (i % self.attn_period) == self.attn_period - 1 else "ssm"
                )
            else:
                kinds.append("attn")
        return tuple(kinds)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        p = self.moe.period
        return tuple((i % p) == p - 1 for i in range(self.n_layers))

    def interleave_period(self) -> int:
        """Smallest homogeneous repeat unit of the layer stack."""
        p = 1
        if self.family == "hybrid":
            p = self.attn_period
        if self.moe is not None:
            p = _lcm(p, self.moe.period)
        return p


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class RunConfig:
    """Training/serving run settings, independent of the architecture."""

    microbatches: int = 1  # grad-accumulation chunks per step
    remat: str = "selective"  # none | selective | full
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    # int8 error-feedback gradient compression over the DP axis
    grad_compression: bool = False
    # fsdp: shard weights over the data axes in addition to TP
    fsdp: bool = True
    # attention lowering: "xla" materializes (B,H,S,S) scores in HBM;
    # "flash" models the Pallas fused kernel (kernels/flash_attention):
    # scores stay in VMEM, only O(S) stats cross HBM.
    attn_kernel: str = "xla"
    # MoE weight strategy: "gather" (FSDP + per-layer gather, train) |
    # "tp2d" (experts x ffn 2D-resident, decode) — see sharding/rules.py
    moe_weight_mode: str = "gather"
    checkpoint_every: int = 500
    keep_checkpoints: int = 3


def sub_quadratic(cfg: ModelConfig) -> bool:
    """Can this arch decode at 500k context with a bounded working set?"""
    if cfg.family in ("ssm", "hybrid"):
        return True
    a = cfg.attention
    return bool(a and a.sliding_window)


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(supported, reason-if-not) for an (arch x shape) cell."""
    if cfg.is_encoder_only and shape.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not sub_quadratic(cfg):
        return False, "full quadratic attention: 500k decode out of scope (DESIGN.md)"
    return True, ""


def cells_for(cfg: ModelConfig) -> Sequence[Tuple[ShapeConfig, bool, str]]:
    return [(s, *cell_supported(cfg, s)) for s in ALL_SHAPES]


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 64,
            vocab: int = 128, seq: int = 32) -> ModelConfig:
    """Smoke-test sized config of the same family (per-arch smoke tests)."""
    scale = d_model / cfg.d_model
    attn = None
    if cfg.attention is not None:
        a = cfg.attention
        heads = max(2, min(4, a.n_heads))
        kv = max(1, min(heads, a.n_kv_heads))
        attn = dataclasses.replace(
            a, n_heads=heads, n_kv_heads=kv, head_dim=max(8, d_model // heads),
            sliding_window=min(a.sliding_window, seq // 2) if a.sliding_window else None,
        )
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(2, cfg.moe.top_k),
            d_ff_expert=max(16, int(cfg.moe.d_ff_expert * scale)),
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    period = cfg.interleave_period()
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(n_layers, min(period, 8)),
        d_model=d_model,
        vocab=vocab,
        d_ff=max(32, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        attention=attn, moe=moe, ssm=ssm,
        frontend_positions=min(cfg.frontend_positions, 8),
    )
