"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    vocab=65536,
    d_ff=24576,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128, causal=True,
                              use_rope=False),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, period=2),
    ssm=SSMConfig(d_state=128, head_dim=128, expand=2, conv_width=4, chunk=256),
    attn_period=8,  # one attention layer per 8 (1:7 attn:mamba)
    act="swiglu",
    norm="rmsnorm",
    source="arXiv:2403.19887; hf",
)
