"""mamba2-130m [ssm] — 24L d768, attention-free, vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    d_ff=0,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    act="swiglu",  # unused by ssm blocks; kept for the shared norm/embed path
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
