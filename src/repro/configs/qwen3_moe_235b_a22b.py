"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H (GQA kv=4) d_ff_expert=1536
vocab=151936, MoE 128e top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    vocab=151936,
    d_ff=0,  # every layer is MoE; no shared dense FFN
    attention=AttentionConfig(
        n_heads=64, n_kv_heads=4, head_dim=128, causal=True, rope_theta=1e6
    ),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536, period=1),
    act="swiglu",
    norm="rmsnorm",
    source="hf:Qwen/Qwen3-235B-A22B; hf",
)
