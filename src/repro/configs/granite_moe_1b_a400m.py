"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8) d_ff_expert=512
vocab=49155, MoE 32e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    vocab=49155,
    d_ff=0,
    attention=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=64, causal=True),
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512, period=1),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
