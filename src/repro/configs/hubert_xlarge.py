"""hubert-xlarge [audio] — 48L d1280 16H (MHA kv=16) d_ff=5120 vocab=504;
encoder-only (no decode shapes), audio frontend stubbed with precomputed
frame embeddings. [arXiv:2106.07447; unverified]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    vocab=504,
    d_ff=5120,
    attention=AttentionConfig(
        n_heads=16, n_kv_heads=16, head_dim=80, causal=False, use_rope=False
    ),
    act="gelu",
    norm="layernorm",
    bidirectional=True,
    is_encoder_only=True,
    frontend="audio",
    frontend_positions=0,  # all positions come from the audio frontend
    source="arXiv:2106.07447; unverified",
)
