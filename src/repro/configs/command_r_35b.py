"""command-r-35b [dense] — 40L d8192 64H (GQA kv=8) d_ff=22528
vocab=256000; GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    vocab=256000,
    d_ff=22528,
    attention=AttentionConfig(
        n_heads=64, n_kv_heads=8, head_dim=128, causal=True, qkv_bias=False
    ),
    act="swiglu",
    norm="layernorm",
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
