"""nemotron-4-15b [dense] — 32L d6144 48H (GQA kv=8) d_ff=24576
vocab=256000; GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    vocab=256000,
    d_ff=24576,
    attention=AttentionConfig(n_heads=48, n_kv_heads=8, head_dim=128, causal=True),
    act="squared_relu",
    norm="layernorm",
    source="arXiv:2402.16819; unverified",
)
