"""internvl2-2b [vlm] — 24L d2048 16H (GQA kv=8) d_ff=8192 vocab=92553;
InternViT frontend (stubbed: precomputed patch embeddings) + InternLM2
backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    vocab=92553,
    d_ff=8192,
    attention=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=128, causal=True),
    act="swiglu",
    norm="rmsnorm",
    frontend="vision",
    frontend_positions=256,  # ViT patch embeddings prepended to the text
    source="arXiv:2404.16821; hf",
)
