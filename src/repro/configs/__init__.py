"""Architecture registry: ``get_config(arch_id)`` resolves ``--arch`` flags."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    cell_supported,
    cells_for,
    reduced,
    sub_quadratic,
)

# arch-id -> module name
_REGISTRY: Dict[str, str] = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "command-r-35b": "command_r_35b",
    "granite-20b": "granite_20b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "nemotron-4-15b": "nemotron_4_15b",
    "internvl2-2b": "internvl2_2b",
    "mamba2-130m": "mamba2_130m",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in ALL_SHAPES]}")
