"""granite-20b [dense] — 52L d6144 48H (MQA kv=1) d_ff=24576 vocab=49152;
llama-arch code model, gpt-bigcode style MQA + learned positions.
[arXiv:2405.04324; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    vocab=49152,
    d_ff=24576,
    attention=AttentionConfig(
        n_heads=48, n_kv_heads=1, head_dim=128, causal=True, use_rope=False,
        qkv_bias=True,
    ),
    act="gelu",
    norm="layernorm",
    source="arXiv:2405.04324; hf",
)
