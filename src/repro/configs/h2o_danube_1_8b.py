"""h2o-danube-1.8b [dense] — 24L d2560 32H (GQA kv=8) d_ff=6912 vocab=32000;
llama+mistral mix with sliding-window attention. [arXiv:2401.16818; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    vocab=32000,
    d_ff=6912,
    attention=AttentionConfig(
        n_heads=32, n_kv_heads=8, head_dim=80, causal=True, sliding_window=4096
    ),
    act="swiglu",
    norm="rmsnorm",
    source="arXiv:2401.16818; hf",
)
