"""KATANA's own workload configs: the paper's filter dimensions.

LKF: n=6 (3-D position + velocity), m=3 (position measurements).
EKF: n=8 (constant-turn-rate with acceleration), m=4.
Batched: N=200 filters per inference call (paper Table I);
``katana_pod`` scales the filter bank across the production mesh.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class KatanaConfig:
    name: str
    filter_kind: str  # "lkf" | "ekf"
    state_dim: int
    meas_dim: int
    batch: int  # N filters per inference call
    dt: float = 1.0 / 30.0  # 30 FPS camera cadence (paper Fig. 5)
    dtype: str = "float32"


LKF_SINGLE = KatanaConfig("katana-lkf", "lkf", state_dim=6, meas_dim=3, batch=1)
EKF_SINGLE = KatanaConfig("katana-ekf", "ekf", state_dim=8, meas_dim=4, batch=1)
LKF_BATCHED = KatanaConfig("katana-lkf-batched", "lkf", 6, 3, batch=200)
EKF_BATCHED = KatanaConfig("katana-ekf-batched", "ekf", 8, 4, batch=200)
# Pod-scale MOT: one bank shard per data-parallel group.
LKF_POD = KatanaConfig("katana-lkf-pod", "lkf", 6, 3, batch=131072)
EKF_POD = KatanaConfig("katana-ekf-pod", "ekf", 8, 4, batch=131072)

ALL = {c.name: c for c in
       (LKF_SINGLE, EKF_SINGLE, LKF_BATCHED, EKF_BATCHED, LKF_POD, EKF_POD)}
