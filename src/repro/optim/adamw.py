"""AdamW with fp32 master weights, global-norm clipping, and optional
int8 error-feedback gradient compression (distributed/compression.py).

TrainState is a plain pytree; every leaf inherits the parameter's
sharding (master/m/v shard identically to the param), so optimizer
memory scales down with FSDP exactly like the weights do.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jnp.ndarray        # () int32
    master: Any              # fp32 param pytree (source of truth)
    m: Any                   # fp32 first moment
    v: Any                   # fp32 second moment
    ef: Optional[Any] = None  # error-feedback residual (compression)


def init_train_state(params, compression: bool = False) -> TrainState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda: jax.tree.map(jnp.zeros_like, master)  # noqa: E731
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        master=master, m=zeros(), v=zeros(),
        ef=zeros() if compression else None,
    )


def abstract_train_state(abstract_params, compression: bool = False):
    return jax.eval_shape(
        lambda p: init_train_state(p, compression), abstract_params)


def compute_params(state: TrainState, dtype) -> Any:
    """bf16 compute view of the master weights."""
    return jax.tree.map(lambda p: p.astype(dtype), state.master)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(state: TrainState, grads, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1) -> TrainState:
    """grads: fp32 pytree matching master."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return p, m, v

    out = jax.tree.map(upd, state.master, grads, state.m, state.v)
    master = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return state._replace(step=step, master=master, m=m, v=v)
