"""Deterministic synthetic LM data pipeline.

Affine-recurrent token streams with segment structure: learnable by a
small LM (loss drops fast), fully seeded, and the iterator state is a
single step counter — checkpoint/restart resumes the stream exactly
(tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class LMStreamState:
    step: int = 0


class LMDataPipeline:
    """Yields {tokens (B, S) int32, labels (B, S) int32} batches.

    labels[t] = tokens[t+1] (next-token prediction). Deterministic in
    (seed, step): batch i is a pure function of its index, so resuming
    from a checkpointed step reproduces the exact stream."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, microbatches: int = 1):
        self.vocab = max(vocab, 8)
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.microbatches = microbatches
        self.state = LMStreamState()

    def _sequence(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed * 1_000_003 + idx) % 2**63)
        a = int(rng.integers(1, 17)) * 2 + 1   # odd multiplier
        b = int(rng.integers(0, self.vocab))
        x = int(rng.integers(0, self.vocab))
        out = np.empty(self.seq + 1, np.int32)
        for t in range(self.seq + 1):
            out[t] = x
            x = (a * x + b) % self.vocab
            if rng.random() < 0.02:  # segment reset (keeps entropy up)
                x = int(rng.integers(0, self.vocab))
        return out

    def next_batch(self) -> Dict[str, np.ndarray]:
        i0 = self.state.step * self.batch
        seqs = np.stack([self._sequence(i0 + i) for i in range(self.batch)])
        self.state.step += 1
        batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        if self.microbatches > 1:
            mb = self.microbatches
            batch = {k: v.reshape(mb, self.batch // mb, self.seq)
                     for k, v in batch.items()}
        return batch

    # -- checkpointable iterator state --
    def state_dict(self) -> Dict:
        return {"step": self.state.step}

    def load_state_dict(self, d: Dict) -> None:
        self.state.step = int(d["step"])
