"""Synthetic tracking scenarios: ground-truth dynamics + noisy detections.

Deterministic (seeded numpy) generators for
  * single-target measurement sequences per filter model (unit tests,
    Table-I style benches),
  * multi-target MOT scenes with birth/death and clutter (tracker tests,
    the end-to-end example — the paper's Fig. 5 analogue without the
    Haar-cascade frontend), and
  * maneuvering targets that switch between straight / coordinated-turn
    / accelerating segments — the model-mismatch regime the IMM bank is
    built for (a single CV filter lags every maneuver; the IMM's CT/CA
    hypotheses pick them up).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core.filters import FilterModel


def single_target(model: FilterModel, T: int, seed: int = 0,
                  meas_noise: float = None) -> Tuple[np.ndarray, np.ndarray]:
    """Simulate the model's own dynamics; returns (truth (T,n), z (T,m))."""
    rng = np.random.default_rng(seed)
    n, m = model.n, model.m
    x = np.array(model.x0, np.float64)
    x[: min(3, n)] += rng.normal(size=min(3, n))  # random start position
    Lq = np.linalg.cholesky(np.asarray(model.Q) + 1e-12 * np.eye(n))
    r = np.sqrt(np.diag(model.R)) if meas_noise is None else meas_noise
    truth = np.zeros((T, n))
    zs = np.zeros((T, m))
    H = np.asarray(model.H)
    for t in range(T):
        if model.is_linear:
            x = np.asarray(model.F) @ x
        else:
            x = model.f_np(x)
        x = x + Lq @ rng.normal(size=n)
        truth[t] = x
        zs[t] = H @ x + r * rng.normal(size=m)
    return truth, zs


def batched_targets(model: FilterModel, T: int, N: int, seed: int = 0):
    """(truth (T,N,n), z (T,N,m)) — N independent targets."""
    truths, zs = [], []
    for k in range(N):
        t, z = single_target(model, T, seed=seed * 100003 + k)
        truths.append(t)
        zs.append(z)
    return np.stack(truths, 1), np.stack(zs, 1)


def maneuvering_target(T: int, dt: float = 1.0 / 30.0, seed: int = 0,
                       speed: float = 3.0, omega: float = 0.7,
                       accel: float = 2.0, meas_noise: float = 0.3,
                       seg_len: int = 40) -> Tuple[np.ndarray, np.ndarray]:
    """One target switching between CV / CT / CA motion segments.

    The truth alternates randomly between straight flight, coordinated
    turns (rate ±omega about z) and along-track acceleration bursts, in
    segments of ~``seg_len`` frames — the classic IMM stress test:
    every mode is exactly one of the IMM hypotheses, but a single CV
    filter mis-models 2/3 of the trajectory.

    Returns (truth (T, 9) in the IMM state layout [p, v, a],
    z (T, 3) noisy position detections).
    """
    rng = np.random.default_rng(seed)
    p = rng.uniform(-5.0, 5.0, 3)
    heading = rng.uniform(0, 2 * np.pi)
    v = np.array([speed * np.cos(heading), speed * np.sin(heading), 0.0])
    truth = np.zeros((T, 9))
    zs = np.zeros((T, 3))
    t = 0
    while t < T:
        mode = rng.choice(["cv", "ct+", "ct-", "ca+", "ca-"])
        dur = int(rng.integers(seg_len // 2, seg_len + seg_len // 2))
        w = omega if mode == "ct+" else -omega
        for _ in range(min(dur, T - t)):
            v_prev = v
            if mode in ("ca+", "ca-"):
                sp = np.linalg.norm(v[:2]) or 1.0
                sign = 1.0 if mode == "ca+" else -1.0
                # accelerate/brake along track (never through zero speed)
                if sign < 0 and sp < 0.5 * speed:
                    sign = 1.0
                v = v + np.append(sign * accel * v[:2] / sp, 0.0) * dt
            elif mode in ("ct+", "ct-"):
                c, s = np.cos(w * dt), np.sin(w * dt)
                v = np.array([c * v[0] - s * v[1], s * v[0] + c * v[1], v[2]])
            p = p + v * dt
            # truth acceleration = the realized dv/dt, so CT segments
            # carry their (centripetal) acceleration, not zero
            truth[t, :3], truth[t, 3:6] = p, v
            truth[t, 6:9] = (v - v_prev) / dt
            zs[t] = p + meas_noise * rng.normal(size=3)
            t += 1
            if t >= T:
                break
    return truth, zs


def maneuvering_batch(T: int, N: int, seed: int = 0,
                      **kw) -> Tuple[np.ndarray, np.ndarray]:
    """(truth (T, N, 9), z (T, N, 3)) — N independent maneuvering
    targets (the IMM benchmark workload)."""
    truths, zs = [], []
    for k in range(N):
        tr, z = maneuvering_target(T, seed=seed * 100003 + k, **kw)
        truths.append(tr)
        zs.append(z)
    return np.stack(truths, 1), np.stack(zs, 1)


@dataclass(frozen=True)
class SceneConfig:
    T: int = 120
    max_targets: int = 12
    birth_rate: float = 0.08     # per-frame probability of a new target
    death_rate: float = 0.005    # per-frame probability a target leaves
    p_detect: float = 0.95
    clutter_rate: float = 1.0    # Poisson mean false alarms per frame
    extent: float = 20.0         # scene half-width
    max_meas: int = 64


def mot_scene(model: FilterModel, cfg: SceneConfig, seed: int = 0):
    """Multi-target scene with birth/death, misses and clutter.

    Returns:
      z      (T, max_meas, m) padded measurements
      valid  (T, max_meas) bool
      truth  list[T] of (id, state) lists  (for metrics)
    """
    rng = np.random.default_rng(seed)
    n, m = model.n, model.m
    H = np.asarray(model.H)
    Lq = np.linalg.cholesky(np.asarray(model.Q) + 1e-12 * np.eye(n))
    r = np.sqrt(np.diag(model.R))

    targets = {}  # id -> state
    next_id = 0
    z_out = np.zeros((cfg.T, cfg.max_meas, m))
    valid = np.zeros((cfg.T, cfg.max_meas), bool)
    truth = []
    for t in range(cfg.T):
        # births
        if len(targets) < cfg.max_targets and (
                t == 0 or rng.random() < cfg.birth_rate):
            x = np.array(model.x0, np.float64)
            x[: min(3, n)] = rng.uniform(-cfg.extent, cfg.extent, min(3, n))
            targets[next_id] = x
            next_id += 1
        # deaths
        for tid in [k for k in targets if rng.random() < cfg.death_rate]:
            del targets[tid]
        # propagate + detect
        meas = []
        frame_truth = []
        for tid in list(targets):
            x = targets[tid]
            x = (np.asarray(model.F) @ x) if model.is_linear else model.f_np(x)
            x = x + Lq @ rng.normal(size=n)
            targets[tid] = x
            frame_truth.append((tid, x.copy()))
            if rng.random() < cfg.p_detect:
                meas.append(H @ x + r * rng.normal(size=m))
        # clutter
        for _ in range(rng.poisson(cfg.clutter_rate)):
            meas.append(rng.uniform(-cfg.extent, cfg.extent, m))
        rng.shuffle(meas)
        meas = meas[: cfg.max_meas]
        for j, zz in enumerate(meas):
            z_out[t, j] = zz
            valid[t, j] = True
        truth.append(frame_truth)
    return z_out, valid, truth
