"""jit'd wrappers for the katana_bank kernels: canonical (N, n) layout
in, lane-packed (n, N) SoA inside, padding N to the lane tile.

Two dispatch granularities:
  ``katana_bank``          one predict+update per call (per-frame).
  ``katana_bank_sequence`` a whole (T, N, m) measurement stream in ONE
        pallas_call — the AoS->SoA transposes and lane padding are paid
        once per sequence instead of once per frame, and x/P stay
        kernel-resident across frames (the time loop is inside the
        kernel, see kernel.make_scan_kernel).

``interpret=True`` everywhere in this container (CPU); on a real TPU
pass interpret=False — the kernels and BlockSpecs are TPU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.filters import FilterModel
from repro.kernels.katana_bank.kernel import (
    LANE_TILE,
    katana_bank_scan_step,
    katana_bank_step,
)


def _pad_to(x, N_pad, axis=-1):
    pad = N_pad - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("model", "lane_tile", "symmetrize",
                                    "interpret"))
def katana_bank(model: FilterModel, x, P, z, lane_tile: int = LANE_TILE,
                symmetrize: bool = True, interpret: bool = True):
    """Fused batched KF step.

    x: (N, n); P: (N, n, n); z: (N, m)  ->  (x', P') same shapes.
    """
    N = x.shape[0]
    N_pad = -(-N // lane_tile) * lane_tile
    # AoS -> SoA (lanes-minor): one transpose outside the kernel; inside,
    # the whole recursion is lane-parallel.
    xs = _pad_to(x.T, N_pad)
    Ps = _pad_to(P.transpose(1, 2, 0), N_pad)
    zs = _pad_to(z.T, N_pad)
    x2, P2 = katana_bank_step(model, xs, Ps, zs, lane_tile=lane_tile,
                              symmetrize=symmetrize, interpret=interpret)
    return x2[:, :N].T, P2[:, :, :N].transpose(2, 0, 1)


@functools.partial(jax.jit,
                   static_argnames=("model", "lane_tile", "symmetrize",
                                    "interpret", "return_final",
                                    "time_chunk"))
def katana_bank_sequence(model: FilterModel, zs, x0, P0,
                         lane_tile: int = LANE_TILE,
                         symmetrize: bool = True, interpret: bool = True,
                         return_final: bool = False,
                         time_chunk: int = 4096):
    """Fused multi-frame filter: one kernel dispatch per sequence.

    zs: (T, N, m); x0: (N, n); P0: (N, n, n)  ->  xs (T, N, n), the
    filtered state after every frame. With ``return_final=True`` also
    returns ``(x_T (N, n), P_T (N, n, n))`` for carrying the bank into
    the next sequence chunk.

    Layout work (lane padding + AoS->SoA transposes) happens ONCE here,
    not per frame; the kernel's fori_loop keeps x/P resident across all
    T steps of a dispatch. The scan kernel holds whole-T zs/xs blocks
    in VMEM, so streams longer than ``time_chunk`` frames run as
    ceil(T / time_chunk) dispatches with (x, P) carried between them —
    the bank still only round-trips HBM once per CHUNK, not per frame.
    """
    zs = jnp.asarray(zs)
    T, N, m = zs.shape
    N_pad = -(-N // lane_tile) * lane_tile
    xs_s = _pad_to(jnp.asarray(x0).T, N_pad)            # (n, N_pad)
    Ps_s = _pad_to(jnp.asarray(P0).transpose(1, 2, 0), N_pad)
    zs_s = _pad_to(zs.transpose(0, 2, 1), N_pad)        # (T, m, N_pad)
    chunks = []
    for t0 in range(0, T, time_chunk):
        xs, xs_s, Ps_s = katana_bank_scan_step(
            model, xs_s, Ps_s, zs_s[t0:t0 + time_chunk],
            lane_tile=lane_tile, symmetrize=symmetrize, interpret=interpret)
        chunks.append(xs)
    xs = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
    out = xs[:, :, :N].transpose(0, 2, 1)               # (T, N, n)
    if return_final:
        return out, (xs_s[:, :N].T, Ps_s[:, :, :N].transpose(2, 0, 1))
    return out


def katana_bank_soa(model: FilterModel, x, P, z, **kw):
    """SoA entry point for callers that keep the lane layout end-to-end
    (the serving engine's resident bank)."""
    return katana_bank_step(model, x, P, z, **kw)
