"""jit'd wrapper for the katana_bank kernel: canonical (N, n) layout in,
lane-packed (n, N) SoA inside, padding N to the lane tile.

``interpret=True`` everywhere in this container (CPU); on a real TPU
pass interpret=False — the kernel and BlockSpecs are TPU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.filters import FilterModel
from repro.kernels.katana_bank.kernel import LANE_TILE, katana_bank_step


def _pad_to(x, N_pad, axis=-1):
    pad = N_pad - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("model", "lane_tile", "symmetrize",
                                    "interpret"))
def katana_bank(model: FilterModel, x, P, z, lane_tile: int = LANE_TILE,
                symmetrize: bool = True, interpret: bool = True):
    """Fused batched KF step.

    x: (N, n); P: (N, n, n); z: (N, m)  ->  (x', P') same shapes.
    """
    N = x.shape[0]
    N_pad = -(-N // lane_tile) * lane_tile
    # AoS -> SoA (lanes-minor): one transpose outside the kernel; inside,
    # the whole recursion is lane-parallel.
    xs = _pad_to(x.T, N_pad)
    Ps = _pad_to(P.transpose(1, 2, 0), N_pad)
    zs = _pad_to(z.T, N_pad)
    x2, P2 = katana_bank_step(model, xs, Ps, zs, lane_tile=lane_tile,
                              symmetrize=symmetrize, interpret=interpret)
    return x2[:, :N].T, P2[:, :, :N].transpose(2, 0, 1)


def katana_bank_soa(model: FilterModel, x, P, z, **kw):
    """SoA entry point for callers that keep the lane layout end-to-end
    (the serving engine's resident bank)."""
    return katana_bank_step(model, x, P, z, **kw)
