"""jit'd wrappers for the katana_bank kernels: canonical (N, n) layout
in, lane-packed (n, N) SoA inside, padding N to the lane tile.

Dispatch granularities:
  ``katana_bank``          one predict+update per call (per-frame).
  ``katana_bank_sequence`` a whole (T, N, m) measurement stream in ONE
        pallas_call — the AoS->SoA transposes and lane padding are paid
        once per sequence instead of once per frame, and x/P stay
        kernel-resident across frames (the time loop is inside the
        kernel, see kernel.make_scan_kernel).
  ``katana_bank_imm``      one IMM multi-model predict+update+loglik
        per call: the K model hypotheses of N tracks flatten to K·N
        stacked lanes of a single padded dispatch (model-major); each
        lane's F/Q/R constants come from a host-folded per-lane table
        indexed inside the kernel (see kernel.plan_imm_tables).
  ``katana_imm_sequence``  the fused IMM fast path: a whole (T, N, m)
        stream through ONE pallas_call per time chunk — mixing, the K
        per-model predict+updates, the mode posterior and the combined
        estimate all run inside the kernel's time loop, so x/P AND mu
        stay kernel-resident across frames and the AoS->SoA packing is
        paid once per sequence (see kernel.make_imm_scan_kernel).
        Supports a per-frame validity mask (coasting frames).
  ``imm_bank_sequence``    the per-frame reference driver: a full IMM
        cycle per frame under one jitted lax.scan — mix ->
        katana_bank_imm -> mode posterior, with the mixing running
        between kernel dispatches. Kept as the independently-built
        equivalence oracle for ``katana_imm_sequence`` (both paths
        require linear member models for K > 1).
  ``katana_frame`` / ``katana_imm_frame``  the LIVE serving frame:
        predict + gated Mahalanobis cost + greedy assignment + update
        (IMM: + mixing, mode posterior, combined estimate) in ONE
        dispatch — what ``tracker.frame_step`` / ``imm_frame_step``
        route through under ``TrackerConfig.fused_frame``; only
        spawn/prune lifecycle bookkeeping stays in XLA.
  ``katana_greedy_assign`` the in-kernel assignment standalone, for
        equivalence testing against ``tracker.greedy_assign``.

Execution mode: every op's ``interpret`` parameter defaults to ``None``
= "resolve from the active execution mode" (``repro.execmode``: the
``KATANA_MODE`` env var / ``TrackerConfig.mode``, with a capability
probe so a ``compiled`` request on a backend that can't lower Pallas —
CPU included — falls back to the interpreter LOUDLY, never silently).
Pass ``interpret=True``/``False`` to pin a path explicitly (the kernel
equivalence tests do). Likewise ``lane_tile``/``time_chunk`` default to
0 = "consult the autotuned table" (``autotune.tuned.json``, keyed on
kernel x bank size x backend x mode), falling back to the static
defaults when no measurement matches. The raw ``kernel.py`` step
functions below this layer stay mode-unaware (explicit ``interpret``
only); ops is where policy is resolved.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import FilterModel, IMMModel
from repro.core.rewrites import imm_combine, imm_mix, imm_mode_posterior
from repro.execmode import resolve_interpret
from repro.kernels.katana_bank.autotune import (tuned_lane_tile,
                                               tuned_time_chunk)
from repro.kernels.katana_bank.kernel import (
    LANE_TILE,
    _selector_rows,
    greedy_assign_step,
    katana_bank_imm_scan_step,
    katana_bank_imm_step,
    katana_bank_scan_step,
    katana_bank_step,
    katana_frame_step,
    katana_imm_frame_step,
    plan_imm_tables,
)

# the frame kernels run grid=(1,) over the whole bank, so the lane pad
# only needs to keep the minor axis register-friendly — 128, not the
# scan kernels' per-program LANE_TILE
FRAME_LANE_PAD = 128


def _pad_to(x, N_pad, axis=-1):
    pad = N_pad - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def katana_bank(model: FilterModel, x, P, z, lane_tile: int = 0,
                symmetrize: bool = True,
                interpret: Optional[bool] = None):
    """Fused batched KF step.

    x: (N, n); P: (N, n, n); z: (N, m)  ->  (x', P') same shapes.
    ``lane_tile=0`` consults the autotuned table; ``interpret=None``
    resolves from the active execution mode.
    """
    interpret = resolve_interpret(interpret)
    lane_tile = lane_tile or tuned_lane_tile("katana_bank", x.shape[0],
                                             LANE_TILE)
    return _katana_bank(model, x, P, z, lane_tile=lane_tile,
                        symmetrize=symmetrize, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("model", "lane_tile", "symmetrize",
                                    "interpret"))
def _katana_bank(model: FilterModel, x, P, z, lane_tile: int,
                 symmetrize: bool, interpret: bool):
    N = x.shape[0]
    N_pad = -(-N // lane_tile) * lane_tile
    # AoS -> SoA (lanes-minor): one transpose outside the kernel; inside,
    # the whole recursion is lane-parallel.
    xs = _pad_to(x.T, N_pad)
    Ps = _pad_to(P.transpose(1, 2, 0), N_pad)
    zs = _pad_to(z.T, N_pad)
    x2, P2 = katana_bank_step(model, xs, Ps, zs, lane_tile=lane_tile,
                              symmetrize=symmetrize, interpret=interpret)
    return x2[:, :N].T, P2[:, :, :N].transpose(2, 0, 1)


def katana_bank_sequence(model: FilterModel, zs, x0, P0,
                         lane_tile: int = 0,
                         symmetrize: bool = True,
                         interpret: Optional[bool] = None,
                         return_final: bool = False,
                         time_chunk: int = 0):
    """Fused multi-frame filter: one kernel dispatch per sequence.

    zs: (T, N, m); x0: (N, n); P0: (N, n, n)  ->  xs (T, N, n), the
    filtered state after every frame. With ``return_final=True`` also
    returns ``(x_T (N, n), P_T (N, n, n))`` for carrying the bank into
    the next sequence chunk.

    Layout work (lane padding + AoS->SoA transposes) happens ONCE here,
    not per frame; the kernel's fori_loop keeps x/P resident across all
    T steps of a dispatch. The scan kernel holds whole-T zs/xs blocks
    in VMEM, so streams longer than ``time_chunk`` frames run as
    ceil(T / time_chunk) dispatches with (x, P) carried between them —
    the bank still only round-trips HBM once per CHUNK, not per frame.
    ``lane_tile=0`` / ``time_chunk=0`` consult the autotuned table
    (static fallbacks LANE_TILE / 4096); ``interpret=None`` resolves
    from the active execution mode.
    """
    N = jnp.shape(zs)[1]
    interpret = resolve_interpret(interpret)
    lane_tile = lane_tile or tuned_lane_tile("katana_bank_sequence", N,
                                             LANE_TILE)
    time_chunk = time_chunk or tuned_time_chunk("katana_bank_sequence", N,
                                                4096)
    return _katana_bank_sequence(model, zs, x0, P0, lane_tile=lane_tile,
                                 symmetrize=symmetrize, interpret=interpret,
                                 return_final=return_final,
                                 time_chunk=time_chunk)


@functools.partial(jax.jit,
                   static_argnames=("model", "lane_tile", "symmetrize",
                                    "interpret", "return_final",
                                    "time_chunk"))
def _katana_bank_sequence(model: FilterModel, zs, x0, P0, lane_tile: int,
                          symmetrize: bool, interpret: bool,
                          return_final: bool, time_chunk: int):
    zs = jnp.asarray(zs)
    T, N, m = zs.shape
    N_pad = -(-N // lane_tile) * lane_tile
    xs_s = _pad_to(jnp.asarray(x0).T, N_pad)            # (n, N_pad)
    Ps_s = _pad_to(jnp.asarray(P0).transpose(1, 2, 0), N_pad)
    zs_s = _pad_to(zs.transpose(0, 2, 1), N_pad)        # (T, m, N_pad)
    chunks = []
    for t0 in range(0, T, time_chunk):
        xs, xs_s, Ps_s = katana_bank_scan_step(
            model, xs_s, Ps_s, zs_s[t0:t0 + time_chunk],
            lane_tile=lane_tile, symmetrize=symmetrize, interpret=interpret)
        chunks.append(xs)
    xs = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
    out = xs[:, :, :N].transpose(0, 2, 1)               # (T, N, n)
    if return_final:
        return out, (xs_s[:, :N].T, Ps_s[:, :, :N].transpose(2, 0, 1))
    return out


def katana_bank_soa(model: FilterModel, x, P, z, **kw):
    """SoA entry point for callers that keep the lane layout end-to-end
    (the serving engine's resident bank)."""
    kw.setdefault("interpret", resolve_interpret(None))
    return katana_bank_step(model, x, P, z, **kw)


def frame_kernel_supported(model) -> bool:
    """True when the fused frame kernel can serve this model: selector
    measurement matrix (every H row a unit vector), and — for a K>1
    IMM — linear member models (constant F tables). The tracker's
    ``fused_frame`` flag falls back to the einsum path when this is
    False, so a general-H or nonlinear-member configuration still
    tracks, just not in one dispatch."""
    if isinstance(model, IMMModel):
        return (_selector_rows(np.asarray(model.H)) is not None
                and (model.K == 1
                     or all(mdl.is_linear for mdl in model.models)))
    return _selector_rows(np.asarray(model.H)) is not None


def katana_frame(model: FilterModel, x, P, z, z_valid, active, gate: float,
                 rounds: int, symmetrize: bool = True,
                 interpret: Optional[bool] = None):
    """Fused live tracking frame: the whole measurement cycle of
    ``tracker.frame_step`` — predict, gate, greedy assignment, update —
    as ONE kernel dispatch.

    x: (C, n); P: (C, n, n); z: (M, m) padded measurements;
    z_valid: (M,) bool; active: (C,) bool; ``gate``/``rounds`` are the
    tracker's (static) chi-square gate and assignment-round bound.
    Returns (x' (C, n), P' (C, n, n), assoc (C,) int32) — predicted
    state where a slot got no measurement, updated where it did, and
    the per-slot measurement index (or -1), byte-identical semantics to
    the einsum path's ``greedy_assign``. Spawn/prune stay with the
    caller. Padding lanes ride along inactive (their zero P predicts to
    P̂ = Q, so S = Q[obs][obs] + R stays invertible) and are sliced
    off."""
    return _katana_frame(model, x, P, z, z_valid, active, gate=gate,
                         rounds=rounds, symmetrize=symmetrize,
                         interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("model", "gate", "rounds", "symmetrize",
                                    "interpret"))
def _katana_frame(model: FilterModel, x, P, z, z_valid, active, gate: float,
                  rounds: int, symmetrize: bool, interpret: bool):
    C = x.shape[0]
    C_pad = -(-C // FRAME_LANE_PAD) * FRAME_LANE_PAD
    xs = _pad_to(x.T, C_pad)
    Ps = _pad_to(P.transpose(1, 2, 0), C_pad)
    act = _pad_to(active.astype(x.dtype)[None, :], C_pad)
    zs = z.T                                           # (m, M)
    zv = z_valid.astype(x.dtype)[None, :]
    x2, P2, assoc = katana_frame_step(model, xs, Ps, zs, zv, act,
                                      gate=gate, rounds=rounds,
                                      symmetrize=symmetrize,
                                      interpret=interpret)
    return (x2[:, :C].T, P2[:, :, :C].transpose(2, 0, 1), assoc[0, :C])


def katana_imm_frame(imm: IMMModel, x, P, mu, z, z_valid, active,
                     gate: float, rounds: int, symmetrize: bool = True,
                     interpret: Optional[bool] = None):
    """Fused live IMM tracking frame (the multi-model ``katana_frame``):
    mixing, K model-conditioned predicts, the cbar-weighted gate, greedy
    assignment, K updates + log-likelihoods, mode posterior and the
    moment-matched combined estimate in ONE dispatch.

    x: (K, C, n); P: (K, C, n, n); mu: (C, K); z: (M, m);
    z_valid: (M,) bool; active: (C,) bool. Returns (x' (K, C, n),
    P' (K, C, n, n), mu' (C, K), x_c (C, n) combined estimates,
    assoc (C,) int32). Coasting slots keep the predicted x̂/P̂ and the
    Markov-predicted cbar, exactly ``bank.update_imm_bank``; spawn and
    prune stay with the caller (``tracker.imm_frame_step``). Padding
    lanes get a uniform mode distribution so their (discarded)
    posterior algebra stays finite."""
    return _katana_imm_frame(imm, x, P, mu, z, z_valid, active, gate=gate,
                             rounds=rounds, symmetrize=symmetrize,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("imm", "gate", "rounds", "symmetrize",
                                    "interpret"))
def _katana_imm_frame(imm: IMMModel, x, P, mu, z, z_valid, active,
                      gate: float, rounds: int, symmetrize: bool,
                      interpret: bool):
    K, C, n = x.shape
    C_pad = -(-C // FRAME_LANE_PAD) * FRAME_LANE_PAD
    xs = _pad_to(x.transpose(0, 2, 1), C_pad)          # (K, n, C_pad)
    Ps = _pad_to(P.transpose(0, 2, 3, 1), C_pad)       # (K, n, n, C_pad)
    mu_s = jnp.pad(mu.T, ((0, 0), (0, C_pad - C)),
                   constant_values=1.0 / K)            # (K, C_pad)
    act = _pad_to(active.astype(x.dtype)[None, :], C_pad)
    zs = z.T                                           # (m, M)
    zv = z_valid.astype(x.dtype)[None, :]
    x2, P2, mu2, xc, assoc = katana_imm_frame_step(
        imm, xs, Ps, mu_s, zs, zv, act, gate=gate, rounds=rounds,
        symmetrize=symmetrize, interpret=interpret)
    return (x2[:, :, :C].transpose(0, 2, 1),
            P2[:, :, :, :C].transpose(0, 3, 1, 2),
            mu2[:, :C].T, xc[:, :C].T, assoc[0, :C])


def katana_greedy_assign(cost, valid, gate: float, rounds: int,
                         interpret: Optional[bool] = None):
    """The frame kernels' in-kernel greedy assignment as a standalone
    dispatch, canonical (C, M) layout — the direct test surface for
    equivalence with ``tracker.greedy_assign``. cost: (C, M);
    valid: (C, M) bool. Returns assoc (C,) int32."""
    return _katana_greedy_assign(cost, valid, gate=gate, rounds=rounds,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("gate", "rounds", "interpret"))
def _katana_greedy_assign(cost, valid, gate: float, rounds: int,
                          interpret: bool):
    C, M = cost.shape
    assoc = greedy_assign_step(cost.T, valid.astype(cost.dtype).T,
                               gate=gate, rounds=rounds,
                               interpret=interpret)
    return assoc[0, :C]


def _imm_lane_table(imm: IMMModel, N: int, L_pad: int,
                    dtype=np.float32) -> np.ndarray:
    """(E, L_pad) host-folded varying-constant table for the model-major
    lane layout: plan_imm_tables' per-model values contracted with the
    (static) one-hot model masks in numpy at trace time — the kernel's
    per-lane "model index" is a finished constant before dispatch.
    Padding lanes get model 0's values so their (discarded) algebra
    stays finite — zeros would fold S to 0 and the emitted 1/det to
    inf."""
    K = imm.K
    _, V = plan_imm_tables(imm.models)  # (E, K)
    sel = np.zeros((K, L_pad), np.float64)
    for k in range(K):
        sel[k, k * N:(k + 1) * N] = 1.0
    sel[0, K * N:] = 1.0
    return (V @ sel).astype(dtype)


def katana_bank_imm(imm: IMMModel, x, P, z, lane_tile: int = 0,
                    symmetrize: bool = True,
                    interpret: Optional[bool] = None):
    """Fused multi-model (IMM) KF step + measurement log-likelihoods.

    x: (K, N, n) model-conditioned means (typically the IMM-mixed
    states); P: (K, N, n, n); z: (N, m) — every model sees the same
    measurement. Returns (x' (K, N, n), P' (K, N, n, n),
    loglik (K, N)).

    The (model, track) product flattens model-major onto the lane axis
    — K·N lanes, padded to the lane tile — so K hypotheses cost one
    kernel dispatch, exactly like K·N plain filters (paper §IV-D's
    batching argument applied to the model index).
    """
    interpret = resolve_interpret(interpret)
    lane_tile = lane_tile or tuned_lane_tile(
        "katana_bank_imm", x.shape[0] * x.shape[1], LANE_TILE)
    return _katana_bank_imm(imm, x, P, z, lane_tile=lane_tile,
                            symmetrize=symmetrize, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("imm", "lane_tile", "symmetrize",
                                    "interpret"))
def _katana_bank_imm(imm: IMMModel, x, P, z, lane_tile: int,
                     symmetrize: bool, interpret: bool):
    K, N, n = x.shape
    m = z.shape[-1]
    L = K * N
    L_pad = -(-L // lane_tile) * lane_tile
    xs = _pad_to(x.reshape(L, n).T, L_pad)
    Ps = _pad_to(P.reshape(L, n, n).transpose(1, 2, 0), L_pad)
    zs = _pad_to(jnp.tile(z, (K, 1)).T, L_pad)
    tab = jnp.asarray(_imm_lane_table(imm, N, L_pad, dtype=x.dtype))
    x2, P2, ll = katana_bank_imm_step(imm, xs, Ps, zs, tab,
                                      lane_tile=lane_tile,
                                      symmetrize=symmetrize,
                                      interpret=interpret)
    return (x2[:, :L].T.reshape(K, N, n),
            P2[:, :, :L].transpose(2, 0, 1).reshape(K, N, n, n),
            ll[0, :L].reshape(K, N))


def katana_imm_sequence(imm: IMMModel, zs, x0, P0, mu0=None, valid=None,
                        lane_tile: int = 0, symmetrize: bool = True,
                        interpret: Optional[bool] = None,
                        return_final: bool = False,
                        time_chunk: int = 0):
    """Fused IMM filtering of a (T, N, m) measurement stream: ONE kernel
    dispatch per time chunk (the ``imm_scan`` stage fast path).

    zs: (T, N, m). x0/P0 seed the bank: (N, n)/(N, n, n) seeds every
    mode identically (fresh tracks), or (K, N, n)/(K, N, n, n) resumes a
    mode-conditioned bank (e.g. a live ``IMMBankState``). mu0: (N, K)
    initial mode probabilities (defaults to ``imm.mu0``). valid:
    optional (T, N) boolean/0-1 mask — a False frame coasts that track
    (time update only, mu <- the Markov-predicted cbar), the tracker's
    no-measurement semantics. Returns xs (T, N, n) moment-matched
    combined estimates; with ``return_final=True`` also
    ``(x (K, N, n), P (K, N, n, n), mu (N, K))`` for chunked streaming.

    ``lane_tile`` here counts TRACKS per program (each program holds all
    K model slabs of its tracks, K·lane_tile lanes); the default 0
    first consults the autotuned table, then falls back to LANE_TILE//K
    so every program keeps the same lane footprint as the single-model
    kernels regardless of K. The ``time_chunk`` fallback (64) is
    deliberately smaller than the single-model sequence's: the IMM scan
    carries K· the block bytes per frame, and bounded chunks also keep
    the backend's in-loop output-block updates from degrading on long
    streams.

    Unlike ``imm_bank_sequence`` (one katana_bank_imm dispatch plus XLA
    mixing per frame), the mixing and mode-posterior algebra run INSIDE
    the scan kernel between the update of frame t and the predict of
    frame t+1: x, P and the mode probabilities are kernel-resident for
    a whole chunk, and the lane padding + AoS->SoA transposes are paid
    once per sequence. K=1 reduces exactly to ``katana_bank_sequence``.
    """
    N = jnp.shape(zs)[1]
    interpret = resolve_interpret(interpret)
    if not lane_tile:
        lane_tile = tuned_lane_tile("katana_imm_sequence", N, 0)
    if not lane_tile:
        # largest power of two <= LANE_TILE / K: keeps the BlockSpec
        # minor dim lane-register-friendly even when K doesn't divide
        # the lane tile (K=3 would otherwise give an 85-wide block)
        lane_tile = 1 << max(3, (LANE_TILE // imm.K).bit_length() - 1)
    time_chunk = time_chunk or tuned_time_chunk("katana_imm_sequence", N, 64)
    return _katana_imm_sequence(imm, zs, x0, P0, mu0, valid,
                                lane_tile=lane_tile, symmetrize=symmetrize,
                                interpret=interpret,
                                return_final=return_final,
                                time_chunk=time_chunk)


@functools.partial(jax.jit,
                   static_argnames=("imm", "lane_tile", "symmetrize",
                                    "interpret", "return_final",
                                    "time_chunk"))
def _katana_imm_sequence(imm: IMMModel, zs, x0, P0, mu0, valid,
                         lane_tile: int, symmetrize: bool, interpret: bool,
                         return_final: bool, time_chunk: int):
    zs = jnp.asarray(zs)
    T, N, m = zs.shape
    K, n = imm.K, imm.n
    x0 = jnp.asarray(x0)
    P0 = jnp.asarray(P0)
    if x0.ndim == 2:
        x0 = jnp.broadcast_to(x0[None], (K, N, n))
    if P0.ndim == 3:
        P0 = jnp.broadcast_to(P0[None], (K, N, n, n))
    mu = (jnp.broadcast_to(jnp.asarray(imm.mu0, zs.dtype), (N, K))
          if mu0 is None else jnp.asarray(mu0))
    N_pad = -(-N // lane_tile) * lane_tile
    xs_s = _pad_to(x0.transpose(0, 2, 1), N_pad)        # (K, n, N_pad)
    Ps_s = _pad_to(P0.transpose(0, 2, 3, 1), N_pad)     # (K, n, n, N_pad)
    # padding lanes get a uniform mode distribution so their (discarded)
    # posterior algebra stays finite — all-zero mu would make the
    # normalizing 1/sum(w) emit inf
    mu_s = jnp.pad(mu.T, ((0, 0), (0, N_pad - N)),
                   constant_values=1.0 / K)              # (K, N_pad)
    if valid is not None:
        # invalid frames never contribute (the kernel selects the
        # prediction), but their z still flows through the emitted
        # update before the select — zero it so a NaN-encoded "no
        # detection" in a replay log cannot poison the carry via 0·NaN
        zs = jnp.where(jnp.asarray(valid, bool)[:, :, None], zs, 0.0)
    zs_s = _pad_to(zs.transpose(0, 2, 1), N_pad)        # (T, m, N_pad)
    vs_s = (None if valid is None else
            _pad_to(jnp.asarray(valid, zs.dtype)[:, None, :], N_pad))
    chunks = []
    for t0 in range(0, T, time_chunk):
        vt = None if vs_s is None else vs_s[t0:t0 + time_chunk]
        xs, xs_s, Ps_s, mu_s = katana_bank_imm_scan_step(
            imm, xs_s, Ps_s, mu_s, zs_s[t0:t0 + time_chunk], vt,
            lane_tile=lane_tile, symmetrize=symmetrize, interpret=interpret)
        chunks.append(xs)
    xs = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
    out = xs[:, :, :N].transpose(0, 2, 1)               # (T, N, n)
    if return_final:
        return out, (xs_s[:, :, :N].transpose(0, 2, 1),
                     Ps_s[:, :, :, :N].transpose(0, 3, 1, 2),
                     mu_s[:, :N].T)
    return out


def imm_bank_sequence(imm: IMMModel, zs, x0, P0, mu0=None,
                      lane_tile: int = 0, symmetrize: bool = True,
                      interpret: Optional[bool] = None,
                      return_final: bool = False):
    """IMM-filter a (T, N, m) measurement stream: one jitted lax.scan,
    one fused multi-model kernel dispatch per frame.

    zs: (T, N, m); x0: (N, n); P0: (N, n, n) seed every mode
    identically; mu0: (N, K) initial mode probabilities (defaults to
    ``imm.mu0``). Returns xs (T, N, n) — the moment-matched combined
    estimate after every frame. With ``return_final=True`` also returns
    ``(x (K, N, n), P (K, N, n, n), mu (N, K))`` for chunked streaming.

    Per frame: IMM mixing (einsum algebra from ``repro.core.rewrites``)
    -> ``katana_bank_imm`` (predict+update+loglik, stacked lanes) ->
    mode posterior from the kernel's log-likelihoods. Mixing between
    dispatches means x/P round-trip HBM (and the packing is re-paid)
    every frame — ``katana_imm_sequence`` is the fused fast path; this
    driver remains as its independently-built equivalence oracle.
    """
    interpret = resolve_interpret(interpret)
    lane_tile = lane_tile or tuned_lane_tile(
        "imm_bank_sequence", imm.K * jnp.shape(zs)[1], LANE_TILE)
    return _imm_bank_sequence(imm, zs, x0, P0, mu0, lane_tile=lane_tile,
                              symmetrize=symmetrize, interpret=interpret,
                              return_final=return_final)


@functools.partial(jax.jit,
                   static_argnames=("imm", "lane_tile", "symmetrize",
                                    "interpret", "return_final"))
def _imm_bank_sequence(imm: IMMModel, zs, x0, P0, mu0, lane_tile: int,
                       symmetrize: bool, interpret: bool,
                       return_final: bool):
    zs = jnp.asarray(zs)
    T, N, m = zs.shape
    K, n = imm.K, imm.n
    x = jnp.broadcast_to(jnp.asarray(x0)[None], (K, N, n))
    P = jnp.broadcast_to(jnp.asarray(P0)[None], (K, N, n, n))
    mu = (jnp.broadcast_to(jnp.asarray(imm.mu0, zs.dtype), (N, K))
          if mu0 is None else jnp.asarray(mu0))
    Pi = jnp.asarray(imm.trans, zs.dtype)

    def body(carry, z_t):
        x, P, mu = carry
        x_mix, P_mix, cbar = imm_mix(x, P, mu, Pi)
        x_new, P_new, ll = katana_bank_imm(imm, x_mix, P_mix, z_t,
                                           lane_tile=lane_tile,
                                           symmetrize=symmetrize,
                                           interpret=interpret)
        mu_new = imm_mode_posterior(cbar, ll)
        x_c, _ = imm_combine(x_new, P_new, mu_new)
        return (x_new, P_new, mu_new), x_c

    (x, P, mu), xs_out = jax.lax.scan(body, (x, P, mu), zs)
    if return_final:
        return xs_out, (x, P, mu)
    return xs_out
