"""Tile autotuner for the katana_bank kernels.

``lane_tile`` (filters per program) and ``time_chunk`` (frames per
dispatch of the scan kernels) are the two knobs that decide how much of
the bank is resident per program and how big each dispatch's VMEM
blocks are. The right values depend on (kernel, shape, backend, mode)
— compiled TPU programs want the 256-lane tile the BlockSpecs were
shaped for, while the interpreter (and small banks) often prefer
smaller tiles — so the measured best per configuration is persisted to
a checked-in table, ``tuned.json`` next to this module, and the ops
wrappers consult it whenever a caller leaves ``lane_tile``/``time_chunk``
at their 0 ("tuned") defaults.

Table format (see docs/benchmarks.md):

    {"format": 1,
     "entries": {
       "<kernel>": {
         "<backend>/<mode>": [
            {"N": 64, "lane_tile": 128, "time_chunk": 32,
             "us_per_frame": 103.2}, ...]}}}

Lookup is by exact ``backend/mode`` key (a CPU/interpret entry never
drives a TPU/compiled run) and nearest ``N`` in log-space within the
matching list; misses fall back to the static defaults, so the table
is purely advisory — deleting it changes no semantics, only speed.
``python -m benchmarks.autotune`` regenerates it.
"""
from __future__ import annotations

import functools
import json
import math
import pathlib
from typing import Dict, Optional

from repro.execmode import ExecMode, active_mode

TUNED_PATH = pathlib.Path(__file__).with_name("tuned.json")
TABLE_FORMAT = 1

# static fallbacks when the table has no matching entry (the historical
# hard-coded defaults, unchanged)
STATIC_DEFAULTS = {
    "katana_bank": dict(lane_tile=256),
    "katana_bank_sequence": dict(lane_tile=256, time_chunk=4096),
    "katana_bank_imm": dict(lane_tile=256),
    "imm_bank_sequence": dict(lane_tile=256),
    # lane_tile 0 keeps the LANE_TILE//K split heuristic in ops
    "katana_imm_sequence": dict(lane_tile=0, time_chunk=64),
}


@functools.lru_cache(maxsize=1)
def _load_table(path_str: str) -> Dict:
    path = pathlib.Path(path_str)
    if not path.exists():
        return {}
    try:
        table = json.loads(path.read_text())
    except (OSError, ValueError):
        return {}
    if table.get("format") != TABLE_FORMAT:
        return {}
    return table.get("entries", {})


def clear_cache() -> None:
    """Drop the cached table (tests rewrite it)."""
    _load_table.cache_clear()


def best_config(kernel: str, N: Optional[int] = None,
                mode: Optional[ExecMode] = None,
                path: Optional[pathlib.Path] = None) -> Dict:
    """The tuned {lane_tile, time_chunk, ...} entry for ``kernel`` at
    bank size ``N`` under ``mode`` (default: the active execution
    mode), or {} when the table has nothing for this configuration."""
    mode = mode or active_mode()
    entries = _load_table(str(path or TUNED_PATH))
    rows = entries.get(kernel, {}).get(f"{mode.backend}/{mode.mode}", [])
    if not rows:
        return {}
    if N is None or N <= 0:
        return dict(rows[0])
    # nearest bank size in log-space: tile choice scales multiplicatively
    best = min(rows, key=lambda r: abs(math.log(max(r.get("N", 1), 1))
                                       - math.log(max(N, 1))))
    return dict(best)


def tuned_lane_tile(kernel: str, N: Optional[int], default: int,
                    mode: Optional[ExecMode] = None) -> int:
    cfg = best_config(kernel, N, mode)
    tile = int(cfg.get("lane_tile", 0)) or default
    return tile


def tuned_time_chunk(kernel: str, N: Optional[int], default: int,
                     mode: Optional[ExecMode] = None) -> int:
    cfg = best_config(kernel, N, mode)
    return int(cfg.get("time_chunk", 0)) or default


def write_table(entries: Dict, path: Optional[pathlib.Path] = None) -> None:
    """Persist an autotuned entries dict (``benchmarks/autotune.py``
    builds it); clears the lookup cache so new defaults apply."""
    path = path or TUNED_PATH
    path.write_text(json.dumps(
        dict(format=TABLE_FORMAT,
             note=("measured best lane_tile/time_chunk per (kernel, "
                   "bank size, backend, execution mode); regenerate "
                   "with `python -m benchmarks.autotune`"),
             entries=entries), indent=2, sort_keys=True) + "\n")
    clear_cache()
