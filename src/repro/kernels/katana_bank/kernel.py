"""katana_bank: fused batched Kalman predict+update Pallas TPU kernel.

This is the TPU-native realization of KATANA's three rewrites (paper
§IV-B/C/D; see docs/paper_mapping.md for the equation-level map):

  Opt-1 (subtract elimination)  -> signs folded into trace-time Python
        constants; the emitted op stream is mul/add only.
  Opt-2 (static fusion)         -> the ENTIRE predict+update recursion
        is one kernel: state x, covariance P, and every intermediate
        live in VMEM/VREGs for the whole step; zero HBM round-trips
        between ops (the TPU analogue of zero DPU<->DSP switches).
  Opt-3 (batching)              -> the filter index N lives on the
        128-lane minor axis ("lane packing"): every per-filter scalar
        in the n x n algebra is an (8,128)-vector op across 128+
        filters. No (N·n)x(N·n) block-diagonal expansion — the N^2
        FLOP blow-up of the paper's NPU formulation disappears.

Beyond the paper, the kernel exploits filter STRUCTURE the NPU's
GEMM-only pipeline could not:
  * selector measurement matrices (H rows are unit vectors, true for
    both paper workloads) turn H P H^T into a covariance row/col
    selection — no GEMM at all;
  * the CTRA Jacobian's sparsity (7 off-identity entries) makes
    F P F^T cost O(nnz·n) lane-ops instead of n^3.

Six kernel shapes share the same emitted step math:

  ``make_kernel``       one predict+update per pallas_call (the
        original per-frame dispatch, still used for single-frame
        serving).
  ``make_scan_kernel``  a (T, m, lane_tile) measurement stream in one
        pallas_call: fori_loop over T inside the kernel body with x and
        P carried in VMEM/VREGs across frames — the sequence-level
        extension of Opt-2. The covariance bank never round-trips
        through HBM between frames. Note the measurement/output blocks
        are whole-T VMEM blocks, so T is VMEM-bounded on real hardware;
        ``ops.katana_bank_sequence`` chunks long streams over
        ``time_chunk``-sized dispatches, carrying (x, P) between them.
  ``make_imm_kernel``   the IMM multi-model step: K motion hypotheses
        run as stacked lanes of one padded bank. Per-model constant
        tables (F, Q, R) are indexed inside the kernel: entries shared
        by every model stay trace-time Python floats (fully folded,
        zeros pruned), and the entries that differ are folded against
        the static model->lane layout ON THE HOST (``plan_imm_tables``)
        into one (E, lane) table input — inside the kernel a per-model
        entry is a single table-row read, so the model "index" costs
        zero arithmetic and the emitted stream stays pure mul/add on
        the matrix path. The kernel additionally emits the per-lane
        measurement log-likelihood from the SAME cofactor S^{-1} it
        computed for the Kalman gain (plus a closed-form determinant) —
        the IMM mode-probability update never inverts anything outside
        the kernel.
  ``make_imm_scan_kernel``  the sequence-level IMM: mixing, the K
        per-model predict+updates, the mode posterior AND the
        moment-matched combination all inside one fori_loop over T —
        a whole K-hypothesis IMM stream is ONE dispatch, with x/P/mu
        VMEM-resident across frames. Each program's block flattens to
        tile-local model-major lanes (the K hypotheses of a track at a
        fixed stride), so mixing reaches across models with static
        slices; shared F/Q/R entries and the (K, K) Markov transition
        matrix fold to trace-time Python floats, model-varying entries
        to loop-invariant lane vectors.
  ``make_frame_kernel`` / ``make_imm_frame_kernel``  the LIVE serving
        frame: predict, innovation + cofactor S^{-1}, the gated
        Mahalanobis cost tile, the greedy assignment (wave-scheduled
        masked argmins over the (M, C) tile, exact vs the sequential
        reference) and the measurement update of the assigned lanes
        (IMM: + mixing, per-lane log-likelihood, mode posterior and
        the moment-matched combined estimate) — the entire closed-loop
        measurement cycle of ``tracker.frame_step`` in ONE dispatch,
        with only spawn/prune lifecycle bookkeeping left in XLA. The
        assignment is a global argmin, so these kernels run grid=(1,)
        over the whole bank instead of tiling the lane axis.

Layout: struct-of-arrays, lanes-minor —
  x (n, N), P (n, n, N), z (m, N) / zs (T, m, N); grid tiles N by
  ``lane_tile``. For the per-frame IMM kernel the lane axis is the
  flattened (model, track) product, model-major across the whole bank;
  the IMM scan kernel carries the model index as a leading block axis
  and flattens it model-major WITHIN each program's tile.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.filters import FilterModel

LANE_TILE = 256  # filters per program: 2 f32 lane-groups


def _selector_rows(H: np.ndarray) -> Optional[List[int]]:
    """If every row of H is a unit vector, return the observed indices."""
    rows = []
    for r in H:
        nz = np.nonzero(r)[0]
        if len(nz) != 1 or abs(r[nz[0]] - 1.0) > 1e-12:
            return None
        rows.append(int(nz[0]))
    return rows


def _mat_from_np(A: np.ndarray):
    """Dense constant matrix -> python list-of-lists of floats (0 pruned
    at emit time)."""
    return [[float(v) for v in row] for row in A]


def _is_zero(v) -> bool:
    return isinstance(v, float) and v == 0.0


def _bc(v, lane):
    """Broadcast a constant-folded entry to a full lane vector at a
    store/stack boundary: python floats (all-zero F rows — e.g. the
    CV9/CT9 IMM models forget their acceleration states — can fold a
    whole entry away) and any under-broadcast array a folded entry
    left behind (shape-mismatched entries would break the fori_loop
    carry structure)."""
    if isinstance(v, (int, float)):
        return jnp.full_like(lane, v)
    return v if v.shape == lane.shape else jnp.broadcast_to(v, lane.shape)


def _emit_dot(row_consts, vec, n):
    """sum_k row[k] * vec[k] with float/lane-vector entries on either
    side; zero terms pruned, 1.0 coefficients elided. Returns 0.0 when
    the whole row folds away."""
    acc = None
    for k in range(n):
        f = row_consts[k]
        if _is_zero(f) or _is_zero(vec[k]):
            continue
        if isinstance(f, float):
            term = vec[k] if f == 1.0 else f * vec[k]
        else:
            term = f * vec[k]
        acc = term if acc is None else acc + term
    return 0.0 if acc is None else acc


def _emit_matvec(F, xv, n):
    """x' = F x on mixed float/lane-vector entries."""
    return [_emit_dot(F[i], xv, n) for i in range(n)]


def _emit_FP(F, P, n):
    """FP = F · P on mixed float/lane-vector entries (zeros pruned) —
    the shared first half of both F P Fᵀ emit paths."""
    return [[_emit_dot(F[i], [P[k][j] for k in range(n)], n)
             for j in range(n)] for i in range(n)]


def _emit_FPFt(F, P, n):
    """P' = F P F^T with F a list-of-lists whose entries are python
    floats (constants) or lane vectors (jnp arrays); zeros pruned."""
    FP = _emit_FP(F, P, n)
    return [[_emit_dot(F[j], FP[i], n) for j in range(n)] for i in range(n)]


def _emit_small_inv(S, m):
    """Cofactor inverse of an m x m matrix of lane vectors (m <= 4)."""
    if m == 1:
        return [[1.0 / S[0][0]]]
    if m == 2:
        det = S[0][0] * S[1][1] - S[0][1] * S[1][0]
        r = 1.0 / det
        return [[S[1][1] * r, -S[0][1] * r], [-S[1][0] * r, S[0][0] * r]]
    if m == 3:
        c00 = S[1][1] * S[2][2] - S[1][2] * S[2][1]
        c01 = S[1][2] * S[2][0] - S[1][0] * S[2][2]
        c02 = S[1][0] * S[2][1] - S[1][1] * S[2][0]
        c10 = S[0][2] * S[2][1] - S[0][1] * S[2][2]
        c11 = S[0][0] * S[2][2] - S[0][2] * S[2][0]
        c12 = S[0][1] * S[2][0] - S[0][0] * S[2][1]
        c20 = S[0][1] * S[1][2] - S[0][2] * S[1][1]
        c21 = S[0][2] * S[1][0] - S[0][0] * S[1][2]
        c22 = S[0][0] * S[1][1] - S[0][1] * S[1][0]
        r = 1.0 / (S[0][0] * c00 + S[0][1] * c01 + S[0][2] * c02)
        return [[c00 * r, c10 * r, c20 * r],
                [c01 * r, c11 * r, c21 * r],
                [c02 * r, c12 * r, c22 * r]]
    if m == 4:
        # Schur on 2x2 blocks, all lane ops
        A = [[S[i][j] for j in range(2)] for i in range(2)]
        B = [[S[i][j + 2] for j in range(2)] for i in range(2)]
        C = [[S[i + 2][j] for j in range(2)] for i in range(2)]
        D = [[S[i + 2][j + 2] for j in range(2)] for i in range(2)]

        def mul2(X, Y):
            return [[X[i][0] * Y[0][j] + X[i][1] * Y[1][j]
                     for j in range(2)] for i in range(2)]

        def sub2(X, Y):
            return [[X[i][j] - Y[i][j] for j in range(2)] for i in range(2)]

        Di = _emit_small_inv(D, 2)
        BDi = mul2(B, Di)
        Si = _emit_small_inv(sub2(A, mul2(BDi, C)), 2)
        DiC = mul2(Di, C)
        TL = Si
        TR = [[-(Si[i][0] * BDi[0][j] + Si[i][1] * BDi[1][j])
               for j in range(2)] for i in range(2)]
        BL = [[-(DiC[i][0] * Si[0][j] + DiC[i][1] * Si[1][j])
               for j in range(2)] for i in range(2)]
        BDiT = mul2(DiC, [[-TR[0][0], -TR[0][1]], [-TR[1][0], -TR[1][1]]])
        BR = [[Di[i][j] + BDiT[i][j] for j in range(2)] for i in range(2)]
        out = [[None] * 4 for _ in range(4)]
        for i in range(2):
            for j in range(2):
                out[i][j] = TL[i][j]
                out[i][j + 2] = TR[i][j]
                out[i + 2][j] = BL[i][j]
                out[i + 2][j + 2] = BR[i][j]
        return out
    raise NotImplementedError(m)


def _emit_det(S, m):
    """Closed-form determinant of an m x m matrix of lane vectors
    (m <= 4) — cofactor expansion, pure mul/add. Feeds the Gaussian
    normalizer of the IMM mode likelihood; the Mahalanobis part reuses
    the S^{-1} already emitted for the Kalman gain, so the likelihood
    adds zero inversions."""
    if m == 1:
        return S[0][0]
    if m == 2:
        return S[0][0] * S[1][1] - S[0][1] * S[1][0]
    if m == 3:
        return (S[0][0] * (S[1][1] * S[2][2] - S[1][2] * S[2][1])
                + S[0][1] * (S[1][2] * S[2][0] - S[1][0] * S[2][2])
                + S[0][2] * (S[1][0] * S[2][1] - S[1][1] * S[2][0]))
    if m == 4:
        # det = det(D) * det(A - B D^{-1} C), 2x2 blocks (Schur)
        A = [[S[i][j] for j in range(2)] for i in range(2)]
        B = [[S[i][j + 2] for j in range(2)] for i in range(2)]
        C = [[S[i + 2][j] for j in range(2)] for i in range(2)]
        D = [[S[i + 2][j + 2] for j in range(2)] for i in range(2)]
        Di = _emit_small_inv(D, 2)
        BDi = [[B[i][0] * Di[0][j] + B[i][1] * Di[1][j]
                for j in range(2)] for i in range(2)]
        Sc = [[A[i][j] - (BDi[i][0] * C[0][j] + BDi[i][1] * C[1][j])
               for j in range(2)] for i in range(2)]
        return _emit_det(D, 2) * _emit_det(Sc, 2)
    raise NotImplementedError(m)


def plan_imm_tables(models):
    """Fold the per-model F/Q/R constant tables for the stacked-lane IMM
    kernel.

    Entries every model agrees on stay trace-time Python floats (fully
    constant-folded, zeros pruned downstream — identical to the
    single-model emit). Entries that differ get a row in the varying-
    entry value matrix V (E, K): ops.py contracts V with the static
    one-hot model-lane masks ON THE HOST, so the kernel receives one
    (E, lane) table input and each varying entry is a single table-row
    read — the per-lane model "indexing" costs zero arithmetic inside
    the kernel (§IV-C constant folding, applied across models).

    Returns (entries, V) where entries[name][i][j] is a float or
    ("var", e) referencing row e of V.
    """
    entries = {}
    vals: List[np.ndarray] = []
    for name in ("F", "Q", "R"):
        Ms = [np.asarray(getattr(mdl, name), np.float64) for mdl in models]
        a, b = Ms[0].shape
        tabl = [[None] * b for _ in range(a)]
        for i in range(a):
            for j in range(b):
                vs = [float(M[i, j]) for M in Ms]
                if all(v == vs[0] for v in vs):
                    tabl[i][j] = vs[0]
                else:
                    tabl[i][j] = ("var", len(vals))
                    vals.append(np.array(vs))
        entries[name] = tabl
    V = np.zeros((max(1, len(vals)), len(models)))  # E >= 1: dummy row
    for e, v in enumerate(vals):                    # keeps BlockSpecs static
        V[e] = v
    return entries, V


def _resolve_mat(tabl, tab):
    """Planned entry table -> float / lane-vector table, reading varying
    entries out of the kernel's (E, lane) table input."""
    return [[cell if isinstance(cell, float) else tab[cell[1]]
             for cell in row] for row in tabl]


_LOG_2PI = float(np.log(2.0 * np.pi))


def _emit_add_Q(Pp, Q, n):
    """P̂ += Q on mixed float/lane entries (zeros pruned)."""
    for i in range(n):
        for j in range(n):
            if not _is_zero(Q[i][j]):
                Pp[i][j] = Pp[i][j] + Q[i][j]
    return Pp


def _emit_predict_cov(F, P, Q, n, sym):
    """P̂ = F P Fᵀ + Q. With ``sym`` (the symmetrize=True contract) only
    the upper triangle is emitted and the mirror entries alias it —
    exact for symmetric P (covariance propagation is symmetric in exact
    arithmetic), and it cuts the dominant n² cost of the step to
    n(n+1)/2 while enforcing symmetry for free (no averaging pass)."""
    if not sym:
        return _emit_add_Q(_emit_FPFt(F, P, n), Q, n)
    FP = _emit_FP(F, P, n)
    Pp = [[None] * n for _ in range(n)]
    for i in range(n):
        for j in range(i, n):
            v = _emit_dot(F[j], FP[i], n)
            if not _is_zero(Q[i][j]):
                v = v + Q[i][j]
            Pp[i][j] = Pp[j][i] = v
    return Pp


def _emit_innovation(Pp, R, obs, n, m):
    """Innovation quantities from the predicted covariance, on lane
    vectors: S = P̂[obs][obs] + R (pure selection for selector H — no
    GEMM), its cofactor inverse, and P̂·Hᵀ (a column selection).
    Returns (S, Sinv, PHt). Split out of ``_emit_update`` so the fused
    frame kernel can aim the SAME S^{-1} at the gating cost tile and
    the Kalman gain — one cofactor inversion per (model, frame), the
    tracker's single-pass discipline emitted in-kernel."""
    S = [[Pp[obs[r]][obs[c]] + R[r][c] if not _is_zero(R[r][c])
          else Pp[obs[r]][obs[c]] for c in range(m)] for r in range(m)]
    PHt = [[Pp[i][obs[r]] for r in range(m)] for i in range(n)]
    Sinv = _emit_small_inv(S, m)
    return S, Sinv, PHt


def _emit_update(xp, Pp, z, R, obs, n, m, symmetrize, with_loglik,
                 inno=None):
    """The fused measurement update on lane vectors (paper §IV-B/C):
    subtract-free innovation (sign folded at trace time), selector-H
    covariance selection instead of H P Hᵀ GEMMs, cofactor S^{-1}.
    Under ``symmetrize`` the posterior covariance is emitted
    upper-triangle-only with aliased mirrors (exact symmetry, ~half the
    covariance-update ops).

    With ``with_loglik`` also emits log N(y; 0, S) per lane from the
    same S^{-1} (+ a closed-form det) — the IMM mode likelihood.
    ``inno`` passes through precomputed ``_emit_innovation`` results
    (the frame kernels, whose gating already paid for them).
    """
    # y = z + H_neg x̂  (Opt-1: sign folded at trace time)
    y = [z[r] - xp[obs[r]] for r in range(m)]
    S, Sinv, PHt = (inno if inno is not None
                    else _emit_innovation(Pp, R, obs, n, m))
    K = [[None] * m for _ in range(n)]
    for i in range(n):
        for r in range(m):
            acc = None
            for c in range(m):
                t = PHt[i][c] * Sinv[c][r]
                acc = t if acc is None else acc + t
            K[i][r] = acc
    # x' = x̂ + K y
    xn = []
    for i in range(n):
        acc = xp[i]
        for r in range(m):
            acc = acc + K[i][r] * y[r]
        xn.append(acc)
    # P' = P̂ + K (H_neg P̂) = P̂ - K P̂[obs, :]
    Pn = [[None] * n for _ in range(n)]
    for i in range(n):
        cols = range(i, n) if symmetrize else range(n)
        for j in cols:
            acc = Pp[i][j]
            for r in range(m):
                acc = acc - K[i][r] * Pp[obs[r]][j]
            Pn[i][j] = acc
            if symmetrize:
                Pn[j][i] = acc  # exact symmetry by aliasing, no averaging
    if not with_loglik:
        return xn, Pn
    # Mahalanobis distance via the S^{-1} above — no second inversion
    d = None
    for r in range(m):
        Sy = None
        for c in range(m):
            t = Sinv[r][c] * y[c]
            Sy = t if Sy is None else Sy + t
        t = y[r] * Sy
        d = t if d is None else d + t
    loglik = -0.5 * (d + jnp.log(_emit_det(S, m)) + m * _LOG_2PI)
    return xn, Pn, loglik


def _check_selector(model: FilterModel) -> List[int]:
    obs = _selector_rows(np.asarray(model.H))
    if obs is None:
        raise NotImplementedError(
            "katana_bank requires a selector measurement matrix (every row "
            "of H a unit vector, true for both paper workloads); for a "
            "general dense H use the 'batched_lanes' rewrite stage instead.")
    return obs


def make_predict_fn(model: FilterModel, symmetrize: bool = True):
    """Emit the time update alone: ``pred(xv, P) -> (x̂, P̂)`` on lane
    vectors. Split out of ``make_step_fn`` so kernels that must keep the
    predicted state live past the update (the fused IMM scan's coasting
    frames select between x̂ and x') emit exactly the same op stream as
    the fused predict+update path."""
    n = model.n
    Qtab = _mat_from_np(np.asarray(model.Q, np.float64))
    Fnp = np.asarray(model.F, np.float64)
    dt = float(model.dt)
    is_linear = model.is_linear

    def pred(xv, P):
        if is_linear:
            F = _mat_from_np(Fnp)
            xp = _emit_matvec(F, xv, n)
        else:
            # CTRA-8: [px,py,pz,v,th,om,a,vz] (paper EKF workload §V)
            px, py, pz, v, th, om, a, vz = xv
            c, s = jnp.cos(th), jnp.sin(th)
            xp = [px + v * c * dt, py + v * s * dt, pz + vz * dt,
                  v + a * dt, th + om * dt, om, a, vz]
            F = [[1.0 if i == j else 0.0 for j in range(n)] for i in range(n)]
            F[0][3] = c * dt
            F[0][4] = -v * s * dt
            F[1][3] = s * dt
            F[1][4] = v * c * dt
            F[2][7] = dt
            F[3][6] = dt
            F[4][5] = dt
        Pp = _emit_predict_cov(F, P, Qtab, n, symmetrize)
        return xp, Pp

    return pred


def make_step_fn(model: FilterModel, symmetrize: bool = True,
                 with_loglik: bool = False):
    """Emit one fused predict+update on lane vectors.

    Returns ``step(xv, P, z) -> (x', P')`` where xv is a length-n list
    of (lane,) vectors, P an n x n nested list of lane vectors, z a
    length-m list (``with_loglik`` appends the per-lane measurement
    log-likelihood). Shared by the per-frame kernel, the multi-frame
    scan kernel and the K=1 IMM degenerate case, so all dispatch shapes
    are numerically identical.
    """
    n, m = model.n, model.m
    obs = _check_selector(model)
    Rtab = _mat_from_np(np.asarray(model.R, np.float64))
    pred = make_predict_fn(model, symmetrize)

    def step(xv, P, z):
        xp, Pp = pred(xv, P)
        return _emit_update(xp, Pp, z, Rtab, obs, n, m, symmetrize,
                            with_loglik)

    return step


def make_imm_step_fn(models, symmetrize: bool = True):
    """Emit one fused multi-model predict+update+log-likelihood.

    ``step(xv, P, z, tab) -> (x', P', loglik)`` where ``tab`` is the
    length-E list of (lane,) folded varying-constant rows (see
    ``plan_imm_tables``): shared F/Q/R entries stay trace-time floats,
    per-model entries are direct table-row reads — the model index
    never leaves the matrix path and costs no runtime arithmetic. K=1
    delegates to ``make_step_fn`` (bitwise the plain bank, which is
    what makes the IMM degenerate case exact).
    """
    if len(models) == 1:
        base = make_step_fn(models[0], symmetrize, with_loglik=True)
        return lambda xv, P, z, tab: base(xv, P, z)
    n, m = models[0].n, models[0].m
    obs = _check_selector(models[0])
    for mdl in models:
        if not mdl.is_linear:
            raise NotImplementedError(
                "multi-model katana_bank_imm requires linear member models "
                "(constant F tables); got " + mdl.name)
        assert (mdl.n, mdl.m) == (n, m)
        assert _check_selector(mdl) == obs
    entries, _ = plan_imm_tables(models)

    def step(xv, P, z, tab):
        F = _resolve_mat(entries["F"], tab)
        Q = _resolve_mat(entries["Q"], tab)
        R = _resolve_mat(entries["R"], tab)
        xp = _emit_matvec(F, xv, n)
        Pp = _emit_predict_cov(F, P, Q, n, symmetrize)
        return _emit_update(xp, Pp, z, R, obs, n, m, symmetrize, True)

    return step


_F32_TINY = float(np.finfo(np.float32).tiny)


def _emit_imm_mix(xv, P, mu, Pi, n, K, tt, sym):
    """IMM interaction (mixing) on model-major flattened lanes: every
    state entry xv[d] / P[r][c] and mu is one (K·tt,) vector whose K
    hypotheses of a track sit a fixed stride ``tt`` apart, so model i's
    slab is the STATIC slice [i·tt, (i+1)·tt) — the K x K interaction
    unrolls into slice / scaled-add ops on (tt,) vectors and one concat
    per mixed entry, keeping the whole frame's op stream 1-D elementwise
    (the shape class this backend executes best: higher-rank
    broadcast/reduce and batched-einsum formulations of the same
    contraction measured 3-6x slower per frame). ``Pi`` is the (K, K)
    transition matrix as trace-time Python floats: zeros prune whole
    terms and ones elide multiplies, §IV-C constant folding applied to
    the Markov chain.

    Returns (x_mix, P_mix, cbar_parts) mirroring ``rewrites.imm_mix``:
    x_mix / P_mix are (K·tt,) vectors, cbar_parts the K per-mode (tt,)
    predicted probabilities. The same tiny-clamped denominator keeps an
    unreachable mode's 0/0 finite, and the spread term
    (x_i - x_mix_j)(·)ᵀ keeps P_mix consistent. Under ``sym`` only the
    upper triangle of P_mix is computed, mirrors aliased.
    """
    mu_i = [mu[i * tt:(i + 1) * tt] for i in range(K)]
    x_i = [[xv[d][i * tt:(i + 1) * tt] for i in range(K)] for d in range(n)]
    cbar_parts, w = [], []
    for j in range(K):
        cj = _emit_dot([Pi[i][j] for i in range(K)], mu_i, K)
        cbar_parts.append(cj)
        rden = 1.0 / jnp.maximum(cj, _F32_TINY)
        w.append([0.0 if Pi[i][j] == 0.0 else
                  (mu_i[i] if Pi[i][j] == 1.0 else Pi[i][j] * mu_i[i]) * rden
                  for i in range(K)])
    # Centered moment form of the spread: with x̃_i = x_i - x_0 (model
    # 0's slab as the per-track reference — the spread is shift
    # invariant, and centering keeps the squared terms at inter-model
    # magnitude, so no cancellation),
    #   Σ_i w_ij (x_i - m_j)(x_i - m_j)ᵀ
    #     = Σ_i w_ij x̃_i x̃_iᵀ - m̃_j m̃_jᵀ,   m̃_j = Σ_i w_ij x̃_i.
    # The per-model squares fold INTO the P contraction (A_i = P_i +
    # x̃ x̃ᵀ, shared across all K targets j) instead of K per-(i, j)
    # outer products — and every model-0 term x̃_0 = 0 prunes away.
    xt = [[0.0 if i == 0 else x_i[d][i] - x_i[d][0] for i in range(K)]
          for d in range(n)]
    mt = [[_emit_dot(w[j], xt[d], K) for j in range(K)] for d in range(n)]
    x_mix = [jnp.concatenate([_bc(mt[d][j] + x_i[d][0], mu_i[0])
                              for j in range(K)]) for d in range(n)]
    P_mix = [[None] * n for _ in range(n)]
    for r in range(n):
        for c in (range(r, n) if sym else range(n)):
            A_i = [P[r][c][i * tt:(i + 1) * tt] if _is_zero(xt[r][i])
                   or _is_zero(xt[c][i])
                   else P[r][c][i * tt:(i + 1) * tt] + xt[r][i] * xt[c][i]
                   for i in range(K)]
            # _bc: a mode with an all-zero transition column folds its
            # whole slab to the float 0.0 (w[j] is all-zero), which
            # jnp.concatenate cannot take
            parts = [_bc(_emit_dot(w[j], A_i, K) - mt[r][j] * mt[c][j],
                         mu_i[0]) for j in range(K)]
            P_mix[r][c] = jnp.concatenate(parts)
            if sym:
                P_mix[c][r] = P_mix[r][c]
    return x_mix, P_mix, cbar_parts


def _emit_mode_posterior(cbar_parts, ll, K, tt):
    """mu'_k ∝ cbar_k exp(ll_k - max ll), per-mode slabs of the (K·tt,)
    log-likelihood vector — the shift-stable mode-probability update
    (``rewrites.imm_mode_posterior`` emitted in-kernel; the max
    guarantees at least one finite weight). Returns the K (tt,)
    posterior slabs."""
    ll_k = [ll[k * tt:(k + 1) * tt] for k in range(K)]
    mx = ll_k[0]
    for k in range(1, K):
        mx = jnp.maximum(mx, ll_k[k])
    ws = [cbar_parts[k] * jnp.exp(ll_k[k] - mx) for k in range(K)]
    s = ws[0]
    for k in range(1, K):
        s = s + ws[k]
    r = 1.0 / s
    return [wk * r for wk in ws]


def _col(v):
    """Lane entry -> (1, lane) row for broadcasting against an
    (M, lane) tile (python floats pass through)."""
    return v if isinstance(v, (int, float)) else v[None, :]


def _emit_cost_tile(z_pred, Sinv, z_rows, m):
    """Squared-Mahalanobis cost tile on lanes-minor layout:
    d[j, c] = yᵀ S_c^{-1} y with y = z_j − ẑ_c. ``z_pred``/``Sinv``
    entries are (lane,) vectors, ``z_rows[r]`` the (M,) r-th coordinate
    of every measurement. Returns the (M, lane) tile, contracted in the
    same order as ``tracker.mahalanobis_cost`` (S^{-1}·y, then y·) so
    the fused and einsum gates see the same float32 rounding."""
    y = [z_rows[r][:, None] - _col(z_pred[r]) for r in range(m)]  # (M, lane)
    d = None
    for r in range(m):
        Sy = None
        for c in range(m):
            t = _col(Sinv[r][c]) * y[c]
            Sy = t if Sy is None else Sy + t
        t = y[r] * Sy
        d = t if d is None else d + t
    return d


_BIG = float(np.finfo(np.float32).max)


def _emit_greedy_assign(cost, act, zval, gate, rounds):
    """Globally-ordered greedy assignment emitted in-kernel, on the
    (M, lane) cost tile (tracks lanes-minor). Exactly
    ``tracker.greedy_assign`` — same gate, same first-occurrence
    (track-major) tie-break, same -1 padding — but wave-scheduled:

    every trip of the loop commits EVERY pair that is simultaneously
    the first argmin of its track row and of its measurement column.
    Any such mutual argmin is provably committed by sequential greedy
    (nothing cheaper can kill its row or column first), committed pairs
    are pairwise row/col-disjoint by construction, and the surviving
    matrix is what sequential greedy would also see — so iterating
    waves reproduces the one-at-a-time result EXACTLY, tie-breaks
    included, while committing many pairs per trip. The global minimum
    is always a mutual argmin, so a wave that commits nothing means
    nothing assignable remains — which makes the early-exit
    ``while_loop`` exact too: ``rounds`` (= min(C, M), the sequential
    bound) caps the trip count, but a typical frame converges in a
    handful of waves instead of paying min(C, M) sequential argmins.

    cost: (M, lane) f32; act: (lane,) 0/1 active-slot mask; zval: (M,)
    0/1 real-measurement mask; gate/rounds are trace-time constants.
    Returns assoc (lane,) int32 — measurement index per track or -1.
    """
    M, C = cost.shape
    BIG = jnp.asarray(_BIG, cost.dtype)
    valid = (act[None, :] > 0) & (zval[:, None] > 0)
    masked = jnp.where(valid & (cost <= gate), cost, BIG)
    iM = jax.lax.broadcasted_iota(jnp.int32, (M, C), 0)
    iC = jax.lax.broadcasted_iota(jnp.int32, (M, C), 1)

    def cond(carry):
        r, go, _, _ = carry
        return go & (r < rounds)

    def body(carry):
        r, _, masked, assoc = carry
        tmin = masked.min(axis=0)                             # (C,)
        targ = jnp.argmin(masked, axis=0).astype(jnp.int32)   # (C,) meas
        marg = jnp.argmin(masked, axis=1).astype(jnp.int32)   # (M,) track
        # mutual-argmin pairs, gather-free: hit[j, c] <=> row c's first
        # argmin is j AND column j's first argmin is c
        hit = (iM == targ[None, :]) & (iC == marg[:, None])
        commit = hit.any(axis=0) & (tmin < BIG)               # (C,)
        assoc = jnp.where(commit, targ, assoc)
        meas_taken = (hit & commit[None, :]).any(axis=1)      # (M,)
        masked = jnp.where(commit[None, :] | meas_taken[:, None], BIG,
                           masked)
        return r + 1, commit.any(), masked, assoc

    assoc0 = jnp.full((C,), -1, jnp.int32)
    carry = (jnp.int32(0), jnp.asarray(True), masked, assoc0)
    *_, assoc = jax.lax.while_loop(cond, body, carry)
    return assoc


def _emit_gather_assigned(assoc, z_rows, m):
    """zk[r] (lane,) = z[assoc, r] via a one-hot contraction (garbage-
    free: unassigned lanes read 0, and their update is select-masked
    away downstream — no dynamic gather, the shape class TPU lanes
    like)."""
    M = z_rows[0].shape[0]
    iM = jax.lax.broadcasted_iota(jnp.int32, (M, assoc.shape[0]), 0)
    onehot = (iM == assoc[None, :]).astype(z_rows[0].dtype)   # (M, lane)
    return [jnp.sum(onehot * z_rows[r][:, None], axis=0) for r in range(m)]


def make_frame_kernel(model: FilterModel, gate: float, rounds: int,
                      symmetrize: bool = True):
    """Build the fused FRAME kernel body: the entire single-model
    measurement cycle — predict, innovation + cofactor S^{-1}, the
    gated Mahalanobis cost tile, the greedy assignment waves, and the
    Kalman update of the assigned lanes — in ONE Pallas dispatch. Only
    spawn/prune lifecycle bookkeeping stays in XLA (``tracker.frame_step``).

    The S^{-1} emitted for the gate IS the S^{-1} of the Kalman gain
    (``_emit_innovation``), so the whole frame still performs exactly
    one cofactor inversion per model. The greedy rounds run as an
    in-kernel ``while_loop`` over the (M, lane) cost tile
    (``_emit_greedy_assign``) — the assignment is a global argmin, so
    the frame kernel runs as a single program over the whole bank
    (grid=(1,)) rather than tiling the lane axis.

    Inputs: x (n, C), P (n, n, C), z (m, M), z_valid (1, M) 0/1,
    active (1, C) 0/1. Outputs: x' (n, C), P' (n, n, C) — predicted
    values where a lane got no measurement, updated where it did —
    and assoc (1, C) int32.
    """
    n, m = model.n, model.m
    obs = _check_selector(model)
    Rtab = _mat_from_np(np.asarray(model.R, np.float64))
    pred = make_predict_fn(model, symmetrize)

    def kernel(x_ref, P_ref, z_ref, zv_ref, act_ref, x_out, P_out, a_out):
        lane = x_ref[0, :]
        xv = [x_ref[i, :] for i in range(n)]
        P = [[P_ref[i, j, :] for j in range(n)] for i in range(n)]
        xp, Pp = pred(xv, P)
        inno = _emit_innovation(Pp, Rtab, obs, n, m)
        _, Sinv, _ = inno
        z_rows = [z_ref[r, :] for r in range(m)]              # (M,)
        z_pred = [xp[obs[r]] for r in range(m)]
        cost = _emit_cost_tile(z_pred, Sinv, z_rows, m)       # (M, C)
        assoc = _emit_greedy_assign(cost, act_ref[0, :], zv_ref[0, :],
                                    gate, rounds)
        zk = _emit_gather_assigned(assoc, z_rows, m)
        xn, Pn = _emit_update(xp, Pp, zk, Rtab, obs, n, m, symmetrize,
                              False, inno=inno)
        upd = (assoc >= 0) & (act_ref[0, :] > 0)
        for i in range(n):
            x_out[i, :] = jnp.where(upd, _bc(xn[i], lane), _bc(xp[i], lane))
            for j in range(n):
                P_out[i, j, :] = jnp.where(upd, _bc(Pn[i][j], lane),
                                           _bc(Pp[i][j], lane))
        a_out[0, :] = assoc

    return kernel


def make_imm_frame_kernel(models, trans, gate: float, rounds: int,
                          symmetrize: bool = True):
    """Build the fused IMM FRAME kernel body: mixing, the K
    model-conditioned predicts, innovation + cofactor S^{-1} per model,
    the cbar-weighted gated cost tile, the greedy assignment waves, the
    K Kalman updates + per-lane log-likelihoods, the mode posterior and
    the moment-matched combined estimate — the whole multi-model
    measurement cycle in ONE dispatch; only spawn/prune stays in XLA
    (``tracker.imm_frame_step``).

    Layout matches ``make_imm_scan_kernel``: blocks arrive as
    x (K, n, C), P (K, n, n, C), mu (K, C) and flatten in-kernel to
    model-major (K·C,) lanes, so the mixing reaches across models with
    static slices and the K predict+updates emit ONE op stream
    (shared F/Q/R entries fold to trace-time floats via
    ``plan_imm_tables``; varying entries become loop-invariant lane
    vectors). The gate weighs each model's Mahalanobis distance by the
    Markov-predicted cbar — exactly ``tracker.imm_frame_step``'s
    mode-probability-weighted gate. Coasting lanes (no measurement)
    keep the predicted x̂/P̂ and the Markov-predicted cbar, matching
    ``bank.update_imm_bank``.

    K=1 skips the mixing/posterior arithmetic and emits exactly
    ``make_frame_kernel``'s op stream with a passthrough mu — the
    degenerate IMM reduces to the plain fused frame, nonlinear (EKF)
    members included.

    Inputs: x (K, n, C), P (K, n, n, C), mu (K, C), z (m, M),
    z_valid (1, M) 0/1, active (1, C) 0/1. Outputs: x' (K, n, C),
    P' (K, n, n, C), mu' (K, C), x_c (n, C) combined estimates,
    assoc (1, C) int32.
    """
    K = len(models)
    n, m = models[0].n, models[0].m
    obs = _check_selector(models[0])
    if K == 1:
        pred = make_predict_fn(models[0], symmetrize)
        entries = V = None
        Rtab0 = _mat_from_np(np.asarray(models[0].R, np.float64))
    else:
        for mdl in models:
            if not mdl.is_linear:
                raise NotImplementedError(
                    "multi-model katana_imm_frame requires linear member "
                    "models (constant F tables); got " + mdl.name)
            assert (mdl.n, mdl.m) == (n, m)
            assert _check_selector(mdl) == obs
        entries, V = plan_imm_tables(models)
        pred = Rtab0 = None
    Pi = [[float(v) for v in row] for row in np.asarray(trans, np.float64)]

    def kernel(x_ref, P_ref, mu_ref, z_ref, zv_ref, act_ref,
               x_out, P_out, mu_out, xc_out, a_out):
        tt = x_ref.shape[-1]
        L = K * tt
        mu = mu_ref[:, :].reshape(L)
        proto = mu
        xv = [x_ref[:, i, :].reshape(L) for i in range(n)]
        P = [[P_ref[:, i, j, :].reshape(L) for j in range(n)]
             for i in range(n)]
        act = act_ref[0, :] > 0                              # (tt,)
        z_rows = [z_ref[r, :] for r in range(m)]             # (M,)
        if K == 1:
            xp, Pp = pred(xv, P)
            inno = _emit_innovation(Pp, Rtab0, obs, n, m)
            _, Sinv, _ = inno
            cost = _emit_cost_tile([xp[obs[r]] for r in range(m)], Sinv,
                                   z_rows, m)
            assoc = _emit_greedy_assign(cost, act_ref[0, :], zv_ref[0, :],
                                        gate, rounds)
            zk = _emit_gather_assigned(assoc, z_rows, m)
            xn, Pn = _emit_update(xp, Pp, zk, Rtab0, obs, n, m, symmetrize,
                                  False, inno=inno)
            upd = (assoc >= 0) & act
            mu_parts = cbar_parts = None
        else:
            dt_ = proto.dtype
            tabv = [jnp.concatenate([jnp.full((tt,), float(v), dt_)
                                     for v in row]) for row in V]
            Ftab, Qtab, Rtab = (_resolve_mat(entries[nm], tabv)
                                for nm in ("F", "Q", "R"))
            x_mix, P_mix, cbar_parts = _emit_imm_mix(
                xv, P, mu, Pi, n, K, tt, symmetrize)
            xp = _emit_matvec(Ftab, x_mix, n)
            Pp = _emit_predict_cov(Ftab, P_mix, Qtab, n, symmetrize)
            inno = _emit_innovation(Pp, Rtab, obs, n, m)
            _, Sinv, _ = inno
            d = _emit_cost_tile([xp[obs[r]] for r in range(m)], Sinv,
                                z_rows, m)                   # (M, K·tt)
            # cbar-weighted gate: sum_k cbar_k · d_k, folded over slabs
            cost = None
            for k in range(K):
                t = _col(cbar_parts[k]) * d[:, k * tt:(k + 1) * tt]
                cost = t if cost is None else cost + t
            assoc = _emit_greedy_assign(cost, act_ref[0, :], zv_ref[0, :],
                                        gate, rounds)
            zk1 = _emit_gather_assigned(assoc, z_rows, m)    # (tt,) each
            zk = [jnp.concatenate([q] * K) for q in zk1]
            xn, Pn, ll = _emit_update(xp, Pp, zk, Rtab, obs, n, m,
                                      symmetrize, True, inno=inno)
            mu_parts = _emit_mode_posterior(cbar_parts, ll, K, tt)
            upd = (assoc >= 0) & act
        # coasting select, exactly bank.update_imm_bank: predicted x̂/P̂
        # where a lane got no measurement, mu <- the Markov cbar
        uL = upd if K == 1 else jnp.concatenate([upd] * K)
        xs = [jnp.where(uL, _bc(xn[i], proto), _bc(xp[i], proto))
              for i in range(n)]
        Ps = [[None] * n for _ in range(n)]
        for i in range(n):
            for j in (range(i, n) if symmetrize else range(n)):
                Ps[i][j] = jnp.where(uL, _bc(Pn[i][j], proto),
                                     _bc(Pp[i][j], proto))
                if symmetrize:
                    Ps[j][i] = Ps[i][j]
        lane1 = act_ref[0, :]                                # float (tt,)
        if K == 1:
            mu_sel = [mu]
            xc = xs
        else:
            mu_sel = [jnp.where(upd, _bc(mu_parts[k], lane1),
                                _bc(cbar_parts[k], lane1)) for k in range(K)]
            xc = [_emit_dot(mu_sel,
                            [u[k * tt:(k + 1) * tt] for k in range(K)], K)
                  for u in xs]
        mu_out[:, :] = jnp.stack([_bc(p, lane1) for p in mu_sel])
        for i in range(n):
            x_out[:, i, :] = xs[i].reshape(K, tt)
            xc_out[i, :] = _bc(xc[i], lane1)
            for j in range(n):
                P_out[:, i, j, :] = Ps[i][j].reshape(K, tt)
        a_out[0, :] = assoc

    return kernel


def make_kernel(model: FilterModel, symmetrize: bool = True):
    """Build the per-frame Pallas kernel body for this filter model."""
    n, m = model.n, model.m
    step = make_step_fn(model, symmetrize)

    def kernel(x_ref, P_ref, z_ref, x_out, P_out):
        xv = [x_ref[i, :] for i in range(n)]
        P = [[P_ref[i, j, :] for j in range(n)] for i in range(n)]
        z = [z_ref[i, :] for i in range(m)]
        xn, Pn = step(xv, P, z)
        lane = x_ref[0, :]
        for i in range(n):
            x_out[i, :] = _bc(xn[i], lane)
            for j in range(n):
                P_out[i, j, :] = _bc(Pn[i][j], lane)

    return kernel


def make_imm_kernel(models, symmetrize: bool = True):
    """Build the multi-model (IMM) Pallas kernel body: the per-frame
    predict+update over K-model stacked lanes, plus the per-lane
    measurement log-likelihood output used by the IMM mode-probability
    update (paper §IV-D batching, reused for the model axis)."""
    n, m = models[0].n, models[0].m
    step = make_imm_step_fn(models, symmetrize)

    def kernel(x_ref, P_ref, z_ref, tab_ref, x_out, P_out, ll_out):
        xv = [x_ref[i, :] for i in range(n)]
        P = [[P_ref[i, j, :] for j in range(n)] for i in range(n)]
        z = [z_ref[i, :] for i in range(m)]
        tab = [tab_ref[e, :] for e in range(tab_ref.shape[0])]
        xn, Pn, ll = step(xv, P, z, tab)
        lane = x_ref[0, :]
        for i in range(n):
            x_out[i, :] = _bc(xn[i], lane)
            for j in range(n):
                P_out[i, j, :] = _bc(Pn[i][j], lane)
        ll_out[0, :] = _bc(ll, lane)

    return kernel


def make_scan_kernel(model: FilterModel, T: int, symmetrize: bool = True):
    """Build the multi-frame Pallas kernel body: fori_loop over T with
    x and P resident in VMEM/VREGs for the whole sequence; each step
    reads one (m, lane) slice of the T-frame measurement block and
    writes one (n, lane) slice of the T-frame output block (both blocks
    live in VMEM for the dispatch — see katana_bank_scan_step on the
    resulting T bound)."""
    n, m = model.n, model.m
    step = make_step_fn(model, symmetrize)

    def kernel(x_ref, P_ref, zs_ref, xs_out, x_fin, P_fin):
        x0 = [x_ref[i, :] for i in range(n)]
        P0 = [[P_ref[i, j, :] for j in range(n)] for i in range(n)]

        def body(t, carry):
            xv, P = carry
            zt = zs_ref[pl.ds(t, 1)]  # (1, m, lane)
            z = [zt[0, r, :] for r in range(m)]
            xn, Pn = step(xv, P, z)
            lane = x_ref[0, :]
            # broadcast any constant-folded entries so the fori_loop
            # carry keeps a uniform (lane,)-vector structure
            xn = [_bc(v, lane) for v in xn]
            Pn = [[_bc(v, lane) for v in row] for row in Pn]
            xs_out[pl.ds(t, 1)] = jnp.stack(xn)[None]
            return xn, Pn

        xT, PT = jax.lax.fori_loop(0, T, body, (x0, P0))
        for i in range(n):
            x_fin[i, :] = xT[i]
            for j in range(n):
                P_fin[i, j, :] = PT[i][j]

    return kernel


def make_imm_scan_kernel(models, trans, T: int, symmetrize: bool = True,
                         with_valid: bool = False):
    """Build the fused IMM multi-frame kernel body: the ENTIRE
    K-hypothesis IMM recursion over T frames inside one fori_loop, with
    the model-conditioned x/P banks AND the mode probabilities mu
    VMEM-resident across frames.

    Layout: blocks arrive as x (K, n, tt), P (K, n, n, tt), mu (K, tt)
    with tt tracks per program; in-kernel every state entry flattens to
    ONE (K·tt,) lane vector, model-major — the K hypotheses of a track
    live at the fixed stride tt in the padded bank, so model i's slab is
    a static slice. That keeps the entire per-frame op stream 1-D
    same-shape elementwise (the class the backend fuses like the
    single-model kernels). The per-model F/Q/R constants fold through
    ``plan_imm_tables``: entries shared by every model stay trace-time
    Python floats (zeros pruned, exactly the single-model emit), entries
    that differ materialize ONCE, outside the time loop, as
    loop-invariant (K·tt,) vectors — so the K model-conditioned
    predict+updates emit ONE op stream whose length is independent of K.
    The (K, K) Markov transition matrix folds to float literals inside
    ``_emit_imm_mix``.

    Per frame t the body emits:
      mix (mode-conditioned reblending of x/P from mu, slice/scaled-add
      over the K slabs)
      -> predict+update over all K models at once (+ the per-(model,
         track) log-likelihood from the same cofactor S^{-1} as the
         Kalman gain)
      -> mode posterior -> moment-matched combined estimate (written to
         xs_out[t]).

    K=1 skips the mixing/posterior arithmetic and emits exactly
    ``make_scan_kernel``'s op stream (the ``imm_scan`` stage reduces
    bitwise to ``fused_scan``, nonlinear members included).

    ``with_valid`` adds a (T, 1, tt) 0/1 measurement-validity input: an
    invalid frame coasts — the carry keeps the predicted x̂/P̂ and the
    Markov-predicted cbar (the tracker's no-measurement semantics), via
    a mul/add select (no control flow, static shapes).
    """
    K = len(models)
    n, m = models[0].n, models[0].m
    obs = _check_selector(models[0])
    if K == 1:
        pred = make_predict_fn(models[0], symmetrize)
        entries = V = None
        Rtab0 = _mat_from_np(np.asarray(models[0].R, np.float64))
    else:
        for mdl in models:
            if not mdl.is_linear:
                raise NotImplementedError(
                    "multi-model imm_scan requires linear member models "
                    "(constant F tables); got " + mdl.name)
            assert (mdl.n, mdl.m) == (n, m)
            assert _check_selector(mdl) == obs
        entries, V = plan_imm_tables(models)
        pred = Rtab0 = None
    Pi = [[float(v) for v in row] for row in np.asarray(trans, np.float64)]

    def kernel(x_ref, P_ref, mu_ref, zs_ref, *rest):
        if with_valid:
            vs_ref, xs_out, x_fin, P_fin, mu_fin = rest
        else:
            xs_out, x_fin, P_fin, mu_fin = rest
        tt = x_ref.shape[-1]
        L = K * tt
        mu0 = mu_ref[:, :].reshape(L)
        proto = mu0  # (K·tt,) broadcast target for _bc
        xv0 = [x_ref[:, i, :].reshape(L) for i in range(n)]
        P0 = [[P_ref[:, i, j, :].reshape(L) for j in range(n)]
              for i in range(n)]
        if K > 1:
            # materialize the model-varying constants once, OUTSIDE the
            # time loop: V[e] (one float per model) -> a loop-invariant
            # (K·tt,) vector whose slab k is the constant for model k
            dt_ = proto.dtype
            tabv = [jnp.concatenate([jnp.full((tt,), float(v), dt_)
                                     for v in row]) for row in V]
            Ftab, Qtab, Rtab = (_resolve_mat(entries[nm], tabv)
                                for nm in ("F", "Q", "R"))
        else:
            Rtab = Rtab0

        def body(t, carry):
            xv, P, mu = carry
            zt = zs_ref[pl.ds(t, 1)]  # (1, m, tt)
            zr = [zt[0, r, :] for r in range(m)]
            if K == 1:
                xp, Pp = pred(xv, P)
                xn, Pn = _emit_update(xp, Pp, zr, Rtab, obs, n, m,
                                      symmetrize, False)
            else:
                # every model slab sees the same measurement
                z = [jnp.concatenate([q] * K) for q in zr]
                x_mix, P_mix, cbar_parts = _emit_imm_mix(
                    xv, P, mu, Pi, n, K, tt, symmetrize)
                xp = _emit_matvec(Ftab, x_mix, n)
                Pp = _emit_predict_cov(Ftab, P_mix, Qtab, n, symmetrize)
                xn, Pn, ll = _emit_update(xp, Pp, z, Rtab, obs, n, m,
                                          symmetrize, True)
                mu_parts = _emit_mode_posterior(cbar_parts, ll, K, tt)
            if with_valid:
                # coasting select: x̂/P̂ where v=0, x'/P' where v=1; mu
                # falls back to the Markov-predicted cbar (still
                # normalized; matches bank.update_imm_bank coasting)
                v = vs_ref[pl.ds(t, 1)][0, 0, :]
                vL = v if K == 1 else jnp.concatenate([v] * K)
                nvL = 1.0 - vL
                xn = [vL * a + nvL * b for a, b in zip(xn, xp)]
                Pc = [[None] * n for _ in range(n)]
                for i in range(n):
                    for j in (range(i, n) if symmetrize else range(n)):
                        Pc[i][j] = vL * Pn[i][j] + nvL * Pp[i][j]
                        if symmetrize:
                            Pc[j][i] = Pc[i][j]
                Pn = Pc
                if K > 1:
                    nv = 1.0 - v
                    mu_parts = [v * a + nv * b
                                for a, b in zip(mu_parts, cbar_parts)]
            # broadcast constant-folded entries: uniform carry structure
            xn = [_bc(u, proto) for u in xn]
            Pn = [[_bc(u, proto) for u in row] for row in Pn]
            # moment-matched combined estimate, (tt,) per state dim
            if K == 1:
                mu_new = mu
                xc = xn
            else:
                mu_new = jnp.concatenate(mu_parts)
                xc = [_emit_dot(mu_parts,
                                [u[k * tt:(k + 1) * tt] for k in range(K)],
                                K) for u in xn]
            xs_out[pl.ds(t, 1)] = jnp.stack(xc)[None]
            return xn, Pn, mu_new

        xT, PT, muT = jax.lax.fori_loop(0, T, body, (xv0, P0, mu0))
        mu_fin[:, :] = muT.reshape(K, tt)
        for i in range(n):
            x_fin[:, i, :] = xT[i].reshape(K, tt)
            for j in range(n):
                P_fin[:, i, j, :] = PT[i][j].reshape(K, tt)

    return kernel


@functools.partial(jax.jit, static_argnames=("model", "lane_tile",
                                             "symmetrize", "interpret"))
def katana_bank_step(model: FilterModel, x, P, z, lane_tile: int = LANE_TILE,
                     symmetrize: bool = True, interpret: bool = True):
    """x: (n, N); P: (n, n, N); z: (m, N) — lanes-minor (SoA) layout.

    N must be a multiple of lane_tile (ops.py pads)."""
    n, m = model.n, model.m
    N = x.shape[-1]
    assert N % lane_tile == 0, (N, lane_tile)
    grid = (N // lane_tile,)
    kern = make_kernel(model, symmetrize)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((m, lane_tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, N), x.dtype),
            jax.ShapeDtypeStruct((n, n, N), P.dtype),
        ],
        interpret=interpret,
    )(x, P, z)


@functools.partial(jax.jit, static_argnames=("imm", "lane_tile",
                                             "symmetrize", "interpret"))
def katana_bank_imm_step(imm, x, P, z, tab, lane_tile: int = LANE_TILE,
                         symmetrize: bool = True, interpret: bool = True):
    """Multi-model fused step over stacked lanes.

    x: (n, L); P: (n, n, L); z: (m, L); tab: (E, L) host-folded
    varying-constant table (``plan_imm_tables`` x the one-hot model
    masks) — lanes-minor (SoA), L = K·N flattened model-major (ops.py
    packs and pads). Returns (x' (n, L), P' (n, n, L), loglik (1, L))."""
    n, m = imm.n, imm.m
    E = tab.shape[0]
    L = x.shape[-1]
    assert L % lane_tile == 0, (L, lane_tile)
    grid = (L // lane_tile,)
    kern = make_imm_kernel(imm.models, symmetrize)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((m, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((E, lane_tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((1, lane_tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, L), x.dtype),
            jax.ShapeDtypeStruct((n, n, L), P.dtype),
            jax.ShapeDtypeStruct((1, L), x.dtype),
        ],
        interpret=interpret,
    )(x, P, z, tab)


@functools.partial(jax.jit, static_argnames=("model", "lane_tile",
                                             "symmetrize", "interpret"))
def katana_bank_scan_step(model: FilterModel, x, P, zs,
                          lane_tile: int = LANE_TILE,
                          symmetrize: bool = True, interpret: bool = True):
    """Whole-sequence fused scan, one pallas_call per sequence.

    x: (n, N); P: (n, n, N); zs: (T, m, N) — lanes-minor (SoA) layout.
    Returns (xs (T, n, N), x_fin (n, N), P_fin (n, n, N)).

    The grid tiles N only; the time loop runs INSIDE the kernel, so the
    covariance bank stays VMEM-resident across all T frames (one HBM
    read of P at entry + one write at exit, vs 2·T round-trips for the
    per-frame dispatch). The zs/xs blocks are whole-T VMEM blocks —
    (T·(m+n)·lane_tile·4 bytes per program), which bounds T to a few
    thousand frames per dispatch on real TPUs; ops.katana_bank_sequence
    chunks longer streams. N must be a multiple of lane_tile (ops.py
    pads)."""
    n, m = model.n, model.m
    T = zs.shape[0]
    N = x.shape[-1]
    assert N % lane_tile == 0, (N, lane_tile)
    grid = (N // lane_tile,)
    kern = make_scan_kernel(model, T, symmetrize)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((T, m, lane_tile), lambda i: (0, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, n, N), x.dtype),
            jax.ShapeDtypeStruct((n, N), x.dtype),
            jax.ShapeDtypeStruct((n, n, N), P.dtype),
        ],
        interpret=interpret,
    )(x, P, zs)


@functools.partial(jax.jit, static_argnames=("imm", "lane_tile",
                                             "symmetrize", "interpret"))
def katana_bank_imm_scan_step(imm, x, P, mu, zs, vs=None,
                              lane_tile: int = LANE_TILE,
                              symmetrize: bool = True,
                              interpret: bool = True):
    """Whole-sequence fused IMM scan, one pallas_call per sequence.

    x: (K, n, N); P: (K, n, n, N); mu: (K, N); zs: (T, m, N) — the track
    index N lanes-minor; ``lane_tile`` counts TRACKS per program, whose
    block flattens in-kernel to K·lane_tile model-major lanes (the K
    hypotheses of a track at stride lane_tile — see
    ``make_imm_scan_kernel``). ``vs``, if given, is a (T, 1, N) 0/1
    validity stream: invalid frames coast (predict only, mu <- cbar).
    Returns (xs (T, n, N) moment-matched combined estimates, x_fin,
    P_fin, mu_fin).

    The grid tiles N only; mixing, the K predict+updates, the mode
    posterior and the combination all run INSIDE the kernel's time loop,
    so an entire IMM stream costs ONE dispatch — x, P and mu never
    round-trip HBM between frames (vs one katana_bank_imm dispatch plus
    XLA mixing per frame in ``ops.imm_bank_sequence``). The same
    whole-T VMEM-block bound as ``katana_bank_scan_step`` applies (at
    K· the block bytes); ``ops.katana_imm_sequence`` chunks longer
    streams."""
    K, n = imm.K, imm.n
    m = imm.m
    T = zs.shape[0]
    N = x.shape[-1]
    assert N % lane_tile == 0, (N, lane_tile)
    grid = (N // lane_tile,)
    kern = make_imm_scan_kernel(imm.models, imm.trans, T, symmetrize,
                                with_valid=vs is not None)
    in_specs = [
        pl.BlockSpec((K, n, lane_tile), lambda i: (0, 0, i)),
        pl.BlockSpec((K, n, n, lane_tile), lambda i: (0, 0, 0, i)),
        pl.BlockSpec((K, lane_tile), lambda i: (0, i)),
        pl.BlockSpec((T, m, lane_tile), lambda i: (0, 0, i)),
    ]
    args = [x, P, mu, zs]
    if vs is not None:
        in_specs.append(pl.BlockSpec((T, 1, lane_tile), lambda i: (0, 0, i)))
        args.append(vs)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((T, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((K, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((K, n, n, lane_tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((K, lane_tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, n, N), x.dtype),
            jax.ShapeDtypeStruct((K, n, N), x.dtype),
            jax.ShapeDtypeStruct((K, n, n, N), P.dtype),
            jax.ShapeDtypeStruct((K, N), mu.dtype),
        ],
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit, static_argnames=("model", "gate", "rounds",
                                             "symmetrize", "interpret"))
def katana_frame_step(model: FilterModel, x, P, z, zval, act, gate: float,
                      rounds: int, symmetrize: bool = True,
                      interpret: bool = True):
    """Whole-frame fused dispatch: predict + gate + greedy-assign +
    update in one pallas_call.

    x: (n, C); P: (n, n, C); z: (m, M); zval: (1, M) 0/1; act: (1, C)
    0/1 — lanes-minor (SoA). Returns (x' (n, C), P' (n, n, C),
    assoc (1, C) int32). The greedy assignment is a GLOBAL argmin over
    the (M, C) cost tile, so the grid is (1,): one program holds the
    whole bank (C·n² f32 ≈ 0.3 MB at C=1024 for n=9 — comfortably
    VMEM-resident; the frame kernel trades the scan kernels' lane
    tiling for whole-bank visibility)."""
    n, m = model.n, model.m
    C = x.shape[-1]
    M = z.shape[-1]
    kern = make_frame_kernel(model, gate, rounds, symmetrize)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, C), lambda i: (0, 0)),
            pl.BlockSpec((n, n, C), lambda i: (0, 0, 0)),
            pl.BlockSpec((m, M), lambda i: (0, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, C), lambda i: (0, 0)),
            pl.BlockSpec((n, n, C), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, C), x.dtype),
            jax.ShapeDtypeStruct((n, n, C), P.dtype),
            jax.ShapeDtypeStruct((1, C), jnp.int32),
        ],
        interpret=interpret,
    )(x, P, z, zval, act)


@functools.partial(jax.jit, static_argnames=("imm", "gate", "rounds",
                                             "symmetrize", "interpret"))
def katana_imm_frame_step(imm, x, P, mu, z, zval, act, gate: float,
                          rounds: int, symmetrize: bool = True,
                          interpret: bool = True):
    """Whole-frame fused IMM dispatch: mix + K predicts + cbar-weighted
    gate + greedy-assign + K updates + mode posterior + combined
    estimate in one pallas_call.

    x: (K, n, C); P: (K, n, n, C); mu: (K, C); z: (m, M); zval: (1, M)
    0/1; act: (1, C) 0/1 — track axis lanes-minor, model-major flatten
    in-kernel (the ``make_imm_scan_kernel`` layout). Returns
    (x' (K, n, C), P' (K, n, n, C), mu' (K, C), x_c (n, C),
    assoc (1, C) int32). grid=(1,) for the same global-argmin reason as
    ``katana_frame_step``."""
    K, n, m = imm.K, imm.n, imm.m
    C = x.shape[-1]
    M = z.shape[-1]
    kern = make_imm_frame_kernel(imm.models, imm.trans, gate, rounds,
                                 symmetrize)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((K, n, C), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, n, n, C), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((K, C), lambda i: (0, 0)),
            pl.BlockSpec((m, M), lambda i: (0, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((K, n, C), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, n, n, C), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((K, C), lambda i: (0, 0)),
            pl.BlockSpec((n, C), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, n, C), x.dtype),
            jax.ShapeDtypeStruct((K, n, n, C), P.dtype),
            jax.ShapeDtypeStruct((K, C), mu.dtype),
            jax.ShapeDtypeStruct((n, C), x.dtype),
            jax.ShapeDtypeStruct((1, C), jnp.int32),
        ],
        interpret=interpret,
    )(x, P, mu, z, zval, act)


@functools.partial(jax.jit, static_argnames=("gate", "rounds", "interpret"))
def greedy_assign_step(cost, valid, gate: float, rounds: int,
                       interpret: bool = True):
    """Standalone dispatch of the in-kernel greedy assignment
    (``_emit_greedy_assign``) for direct equivalence testing against
    ``tracker.greedy_assign``: cost (M, C) lanes-minor, valid (M, C)
    0/1 -> assoc (1, C) int32."""
    M, C = cost.shape

    def kern(cost_ref, valid_ref, a_out):
        cost = cost_ref[:, :]
        # fold the 2-D pair validity through the per-axis masks the
        # frame kernels use: rows of an all-ones act/zval, entrywise
        # invalid pairs pushed past the gate
        vbad = valid_ref[:, :] <= 0
        big = jnp.asarray(_BIG, cost.dtype)
        cost = jnp.where(vbad, big, cost)
        ones_c = jnp.ones((C,), cost.dtype)
        ones_m = jnp.ones((M,), cost.dtype)
        a_out[0, :] = _emit_greedy_assign(cost, ones_c, ones_m, gate, rounds)

    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((M, C), lambda i: (0, 0)),
                  pl.BlockSpec((M, C), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, C), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, C), jnp.int32),
        interpret=interpret,
    )(cost, valid)
