"""katana_bank: fused batched Kalman predict+update Pallas TPU kernel.

This is the TPU-native realization of KATANA's three rewrites
(DESIGN.md §2):

  Opt-1 (subtract elimination)  -> signs folded into trace-time Python
        constants; the emitted op stream is mul/add only.
  Opt-2 (static fusion)         -> the ENTIRE predict+update recursion
        is one kernel: state x, covariance P, and every intermediate
        live in VMEM/VREGs for the whole step; zero HBM round-trips
        between ops (the TPU analogue of zero DPU<->DSP switches).
  Opt-3 (batching)              -> the filter index N lives on the
        128-lane minor axis ("lane packing"): every per-filter scalar
        in the n x n algebra is an (8,128)-vector op across 128+
        filters. No (N·n)x(N·n) block-diagonal expansion — the N^2
        FLOP blow-up of the paper's NPU formulation disappears.

Beyond the paper, the kernel exploits filter STRUCTURE the NPU's
GEMM-only pipeline could not:
  * selector measurement matrices (H rows are unit vectors, true for
    both paper workloads) turn H P H^T into a covariance row/col
    selection — no GEMM at all;
  * the CTRA Jacobian's sparsity (7 off-identity entries) makes
    F P F^T cost O(nnz·n) lane-ops instead of n^3.

Two kernel shapes share the same emitted step math (``make_step_fn``):

  ``make_kernel``       one predict+update per pallas_call (the
        original per-frame dispatch, still used for single-frame
        serving).
  ``make_scan_kernel``  a (T, m, lane_tile) measurement stream in one
        pallas_call: fori_loop over T inside the kernel body with x and
        P carried in VMEM/VREGs across frames — the sequence-level
        extension of Opt-2. The covariance bank never round-trips
        through HBM between frames. Note the measurement/output blocks
        are whole-T VMEM blocks, so T is VMEM-bounded on real hardware;
        ``ops.katana_bank_sequence`` chunks long streams over
        ``time_chunk``-sized dispatches, carrying (x, P) between them.

Layout: struct-of-arrays, lanes-minor —
  x (n, N), P (n, n, N), z (m, N) / zs (T, m, N); grid tiles N by
  ``lane_tile``.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.filters import FilterModel

LANE_TILE = 256  # filters per program: 2 f32 lane-groups


def _selector_rows(H: np.ndarray) -> Optional[List[int]]:
    """If every row of H is a unit vector, return the observed indices."""
    rows = []
    for r in H:
        nz = np.nonzero(r)[0]
        if len(nz) != 1 or abs(r[nz[0]] - 1.0) > 1e-12:
            return None
        rows.append(int(nz[0]))
    return rows


def _sym(M, n):
    for i in range(n):
        for j in range(i + 1, n):
            v = 0.5 * (M[i][j] + M[j][i])
            M[i][j] = v
            M[j][i] = v
    return M


def _mat_from_np(A: np.ndarray):
    """Dense constant matrix -> python list-of-lists of floats (0 pruned
    at emit time)."""
    return [[float(v) for v in row] for row in A]


def _emit_FPFt(F, P, n):
    """P' = F P F^T with F a list-of-lists whose entries are python
    floats (constants) or lane vectors (jnp arrays); zeros pruned."""

    def dot_row(i, col):
        acc = None
        for k in range(n):
            f = F[i][k]
            if isinstance(f, float):
                if f == 0.0:
                    continue
                term = P[k][col] if f == 1.0 else f * P[k][col]
            else:
                term = f * P[k][col]
            acc = term if acc is None else acc + term
        return acc

    FP = [[dot_row(i, j) for j in range(n)] for i in range(n)]

    def dot_col(row, j):
        acc = None
        for k in range(n):
            f = F[j][k]
            if isinstance(f, float):
                if f == 0.0:
                    continue
                term = FP[row][k] if f == 1.0 else f * FP[row][k]
            else:
                term = f * FP[row][k]
            acc = term if acc is None else acc + term
        return acc

    return [[dot_col(i, j) for j in range(n)] for i in range(n)]


def _emit_small_inv(S, m):
    """Cofactor inverse of an m x m matrix of lane vectors (m <= 4)."""
    if m == 1:
        return [[1.0 / S[0][0]]]
    if m == 2:
        det = S[0][0] * S[1][1] - S[0][1] * S[1][0]
        r = 1.0 / det
        return [[S[1][1] * r, -S[0][1] * r], [-S[1][0] * r, S[0][0] * r]]
    if m == 3:
        c00 = S[1][1] * S[2][2] - S[1][2] * S[2][1]
        c01 = S[1][2] * S[2][0] - S[1][0] * S[2][2]
        c02 = S[1][0] * S[2][1] - S[1][1] * S[2][0]
        c10 = S[0][2] * S[2][1] - S[0][1] * S[2][2]
        c11 = S[0][0] * S[2][2] - S[0][2] * S[2][0]
        c12 = S[0][1] * S[2][0] - S[0][0] * S[2][1]
        c20 = S[0][1] * S[1][2] - S[0][2] * S[1][1]
        c21 = S[0][2] * S[1][0] - S[0][0] * S[1][2]
        c22 = S[0][0] * S[1][1] - S[0][1] * S[1][0]
        r = 1.0 / (S[0][0] * c00 + S[0][1] * c01 + S[0][2] * c02)
        return [[c00 * r, c10 * r, c20 * r],
                [c01 * r, c11 * r, c21 * r],
                [c02 * r, c12 * r, c22 * r]]
    if m == 4:
        # Schur on 2x2 blocks, all lane ops
        A = [[S[i][j] for j in range(2)] for i in range(2)]
        B = [[S[i][j + 2] for j in range(2)] for i in range(2)]
        C = [[S[i + 2][j] for j in range(2)] for i in range(2)]
        D = [[S[i + 2][j + 2] for j in range(2)] for i in range(2)]

        def mul2(X, Y):
            return [[X[i][0] * Y[0][j] + X[i][1] * Y[1][j]
                     for j in range(2)] for i in range(2)]

        def sub2(X, Y):
            return [[X[i][j] - Y[i][j] for j in range(2)] for i in range(2)]

        Di = _emit_small_inv(D, 2)
        BDi = mul2(B, Di)
        Si = _emit_small_inv(sub2(A, mul2(BDi, C)), 2)
        DiC = mul2(Di, C)
        TL = Si
        TR = [[-(Si[i][0] * BDi[0][j] + Si[i][1] * BDi[1][j])
               for j in range(2)] for i in range(2)]
        BL = [[-(DiC[i][0] * Si[0][j] + DiC[i][1] * Si[1][j])
               for j in range(2)] for i in range(2)]
        BDiT = mul2(DiC, [[-TR[0][0], -TR[0][1]], [-TR[1][0], -TR[1][1]]])
        BR = [[Di[i][j] + BDiT[i][j] for j in range(2)] for i in range(2)]
        out = [[None] * 4 for _ in range(4)]
        for i in range(2):
            for j in range(2):
                out[i][j] = TL[i][j]
                out[i][j + 2] = TR[i][j]
                out[i + 2][j] = BL[i][j]
                out[i + 2][j + 2] = BR[i][j]
        return out
    raise NotImplementedError(m)


def make_step_fn(model: FilterModel, symmetrize: bool = True):
    """Emit one fused predict+update on lane vectors.

    Returns ``step(xv, P, z) -> (x', P')`` where xv is a length-n list
    of (lane,) vectors, P an n x n nested list of lane vectors, z a
    length-m list. Shared by the per-frame kernel and the multi-frame
    scan kernel so both dispatch shapes are numerically identical.
    """
    n, m = model.n, model.m
    obs = _selector_rows(np.asarray(model.H))
    if obs is None:
        raise NotImplementedError(
            "katana_bank requires a selector measurement matrix (every row "
            "of H a unit vector, true for both paper workloads); for a "
            "general dense H use the 'batched_lanes' rewrite stage instead.")
    Qnp = np.asarray(model.Q, np.float64)
    Rnp = np.asarray(model.R, np.float64)
    Fnp = np.asarray(model.F, np.float64)
    dt = float(model.dt)
    is_linear = model.is_linear

    def step(xv, P, z):
        # ---- predict ----
        if is_linear:
            F = _mat_from_np(Fnp)
            xp = []
            for i in range(n):
                acc = None
                for j in range(n):
                    f = F[i][j]
                    if f == 0.0:
                        continue
                    t = xv[j] if f == 1.0 else f * xv[j]
                    acc = t if acc is None else acc + t
                xp.append(acc)
        else:
            # CTRA-8: [px,py,pz,v,th,om,a,vz] (paper EKF workload)
            px, py, pz, v, th, om, a, vz = xv
            c, s = jnp.cos(th), jnp.sin(th)
            xp = [px + v * c * dt, py + v * s * dt, pz + vz * dt,
                  v + a * dt, th + om * dt, om, a, vz]
            F = [[1.0 if i == j else 0.0 for j in range(n)] for i in range(n)]
            F[0][3] = c * dt
            F[0][4] = -v * s * dt
            F[1][3] = s * dt
            F[1][4] = v * c * dt
            F[2][7] = dt
            F[3][6] = dt
            F[4][5] = dt
        Pp = _emit_FPFt(F if not is_linear else _mat_from_np(Fnp), P, n)
        for i in range(n):
            for j in range(n):
                q = float(Qnp[i, j])
                if q != 0.0:
                    Pp[i][j] = Pp[i][j] + q

        # ---- update (selector-H: S is covariance selection, no GEMM) ----
        # y = z + H_neg x̂  (Opt-1: sign folded at trace time)
        y = [z[r] - xp[obs[r]] for r in range(m)]
        # S = P[obs][obs] + R — pure selection
        S = [[Pp[obs[r]][obs[c]] + float(Rnp[r, c]) for c in range(m)]
             for r in range(m)]
        PHt = [[Pp[i][obs[r]] for r in range(m)] for i in range(n)]
        Sinv = _emit_small_inv(S, m)
        K = [[None] * m for _ in range(n)]
        for i in range(n):
            for r in range(m):
                acc = None
                for c in range(m):
                    t = PHt[i][c] * Sinv[c][r]
                    acc = t if acc is None else acc + t
                K[i][r] = acc
        # x' = x̂ + K y
        xn = []
        for i in range(n):
            acc = xp[i]
            for r in range(m):
                acc = acc + K[i][r] * y[r]
            xn.append(acc)
        # P' = P̂ + K (H_neg P̂) = P̂ - K P̂[obs, :]
        Pn = [[None] * n for _ in range(n)]
        for i in range(n):
            for j in range(n):
                acc = Pp[i][j]
                for r in range(m):
                    acc = acc - K[i][r] * Pp[obs[r]][j]
                Pn[i][j] = acc
        if symmetrize:
            Pn = _sym(Pn, n)
        return xn, Pn

    return step


def make_kernel(model: FilterModel, symmetrize: bool = True):
    """Build the per-frame Pallas kernel body for this filter model."""
    n, m = model.n, model.m
    step = make_step_fn(model, symmetrize)

    def kernel(x_ref, P_ref, z_ref, x_out, P_out):
        xv = [x_ref[i, :] for i in range(n)]
        P = [[P_ref[i, j, :] for j in range(n)] for i in range(n)]
        z = [z_ref[i, :] for i in range(m)]
        xn, Pn = step(xv, P, z)
        for i in range(n):
            x_out[i, :] = xn[i]
            for j in range(n):
                P_out[i, j, :] = Pn[i][j]

    return kernel


def make_scan_kernel(model: FilterModel, T: int, symmetrize: bool = True):
    """Build the multi-frame Pallas kernel body: fori_loop over T with
    x and P resident in VMEM/VREGs for the whole sequence; each step
    reads one (m, lane) slice of the T-frame measurement block and
    writes one (n, lane) slice of the T-frame output block (both blocks
    live in VMEM for the dispatch — see katana_bank_scan_step on the
    resulting T bound)."""
    n, m = model.n, model.m
    step = make_step_fn(model, symmetrize)

    def kernel(x_ref, P_ref, zs_ref, xs_out, x_fin, P_fin):
        x0 = [x_ref[i, :] for i in range(n)]
        P0 = [[P_ref[i, j, :] for j in range(n)] for i in range(n)]

        def body(t, carry):
            xv, P = carry
            zt = zs_ref[pl.ds(t, 1)]  # (1, m, lane)
            z = [zt[0, r, :] for r in range(m)]
            xn, Pn = step(xv, P, z)
            xs_out[pl.ds(t, 1)] = jnp.stack(xn)[None]
            return xn, Pn

        xT, PT = jax.lax.fori_loop(0, T, body, (x0, P0))
        for i in range(n):
            x_fin[i, :] = xT[i]
            for j in range(n):
                P_fin[i, j, :] = PT[i][j]

    return kernel


@functools.partial(jax.jit, static_argnames=("model", "lane_tile",
                                             "symmetrize", "interpret"))
def katana_bank_step(model: FilterModel, x, P, z, lane_tile: int = LANE_TILE,
                     symmetrize: bool = True, interpret: bool = True):
    """x: (n, N); P: (n, n, N); z: (m, N) — lanes-minor (SoA) layout.

    N must be a multiple of lane_tile (ops.py pads)."""
    n, m = model.n, model.m
    N = x.shape[-1]
    assert N % lane_tile == 0, (N, lane_tile)
    grid = (N // lane_tile,)
    kern = make_kernel(model, symmetrize)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((m, lane_tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, N), x.dtype),
            jax.ShapeDtypeStruct((n, n, N), P.dtype),
        ],
        interpret=interpret,
    )(x, P, z)


@functools.partial(jax.jit, static_argnames=("model", "lane_tile",
                                             "symmetrize", "interpret"))
def katana_bank_scan_step(model: FilterModel, x, P, zs,
                          lane_tile: int = LANE_TILE,
                          symmetrize: bool = True, interpret: bool = True):
    """Whole-sequence fused scan, one pallas_call per sequence.

    x: (n, N); P: (n, n, N); zs: (T, m, N) — lanes-minor (SoA) layout.
    Returns (xs (T, n, N), x_fin (n, N), P_fin (n, n, N)).

    The grid tiles N only; the time loop runs INSIDE the kernel, so the
    covariance bank stays VMEM-resident across all T frames (one HBM
    read of P at entry + one write at exit, vs 2·T round-trips for the
    per-frame dispatch). The zs/xs blocks are whole-T VMEM blocks —
    (T·(m+n)·lane_tile·4 bytes per program), which bounds T to a few
    thousand frames per dispatch on real TPUs; ops.katana_bank_sequence
    chunks longer streams. N must be a multiple of lane_tile (ops.py
    pads)."""
    n, m = model.n, model.m
    T = zs.shape[0]
    N = x.shape[-1]
    assert N % lane_tile == 0, (N, lane_tile)
    grid = (N // lane_tile,)
    kern = make_scan_kernel(model, T, symmetrize)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((T, m, lane_tile), lambda i: (0, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, n, lane_tile), lambda i: (0, 0, i)),
            pl.BlockSpec((n, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((n, n, lane_tile), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, n, N), x.dtype),
            jax.ShapeDtypeStruct((n, N), x.dtype),
            jax.ShapeDtypeStruct((n, n, N), P.dtype),
        ],
        interpret=interpret,
    )(x, P, zs)
