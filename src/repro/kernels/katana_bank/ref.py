"""Pure-jnp oracle for the katana_bank kernel: the batched_lanes rewrite
(itself validated against the float64 numpy oracle in core/ref.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.filters import FilterModel
from repro.core.rewrites import build_batched_lanes


def katana_bank_ref(model: FilterModel, x, P, z, symmetrize: bool = True):
    """x: (N, n); P: (N, n, n); z: (N, m) — canonical (AoS) layout."""
    step, _ = build_batched_lanes(model, x.shape[0], dtype=x.dtype,
                                  symmetrize=symmetrize)
    return step(x, P, z)
