"""Pure-jnp oracles for the katana_bank kernels.

``katana_bank_ref`` is the batched_lanes rewrite (itself validated
against the float64 numpy oracle in core/ref.py); ``katana_imm_ref``
is the multi-model step: per-model batched_lanes + the Gaussian
measurement log-likelihood, in plain einsum form — what the stacked-lane
IMM kernel must reproduce per lane.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.filters import FilterModel, IMMModel
from repro.core.rewrites import (build_batched_lanes, gaussian_loglik,
                                 small_det)


def katana_bank_ref(model: FilterModel, x, P, z, symmetrize: bool = True):
    """x: (N, n); P: (N, n, n); z: (N, m) — canonical (AoS) layout."""
    step, _ = build_batched_lanes(model, x.shape[0], dtype=x.dtype,
                                  symmetrize=symmetrize)
    return step(x, P, z)


def katana_imm_ref(imm: IMMModel, x, P, z, symmetrize: bool = True):
    """Multi-model step oracle: x (K, N, n); P (K, N, n, n); z (N, m).

    Returns (x' (K, N, n), P' (K, N, n, n), loglik (K, N)) — each model
    filtered independently on the shared measurement through the SAME
    einsum helpers the IMM tracker bank uses
    (``bank._predict_lanes`` / ``bank._kalman_update_lanes``), which is
    exactly what the kernel's table-folded constants must compute
    lane-for-lane. The log-likelihood uses the same cofactor
    S^{-1}/det algebra (``small_inv``/``small_det``) as the emitted
    kernel.
    """
    from repro.core.bank import _kalman_update_lanes, _predict_lanes

    m = imm.m
    xs, Ps, lls = [], [], []
    for k, model in enumerate(imm.models):
        x_pred, P_pred, z_pred, S, Sinv, PHt = _predict_lanes(
            model, x[k], P[k], x.dtype)
        x_new, P_new = _kalman_update_lanes(model, x_pred, P_pred, z, PHt,
                                            Sinv, x.dtype)
        if not symmetrize:
            # _kalman_update_lanes always symmetrizes; the kernels only
            # do so under the symmetrize contract
            raise NotImplementedError("katana_imm_ref is symmetrize-only")
        xs.append(x_new)
        Ps.append(P_new)
        lls.append(gaussian_loglik(z - z_pred, Sinv,
                                   jnp.log(small_det(S, m)), m))
    return jnp.stack(xs), jnp.stack(Ps), jnp.stack(lls)
