"""jit'd wrapper for ssd_scan: models/ssm.py layout in, kernel layout
inside."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bhsp


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, Bm, Cm, A, chunk: int = 256, interpret: bool = True):
    """Drop-in for models.ssm.ssd_chunked's y output (state0=None).

    x: (B, S, H, P); dt: (B, S, H) fp32 post-softplus; Bm/Cm: (B, S, N);
    A: (H,) negative. Returns y (B, S, H, P).
    """
    xb = x.transpose(0, 2, 1, 3)           # (B, H, S, P)
    dtb = dt.transpose(0, 2, 1)            # (B, H, S)
    y = ssd_scan_bhsp(xb, dtb, Bm, Cm, A[:, None].astype(jnp.float32),
                      chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3)
