"""Oracles for ssd_scan: a naive sequential SSM recurrence (ground
truth) and the chunked pure-jnp implementation from models/ssm.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked  # noqa: F401  (chunked oracle)


def ssd_naive(x, dt, Bm, Cm, A, state0=None):
    """Sequential scan, one step at a time (float32).

    x: (B, S, H, P); dt: (B, S, H); Bm/Cm: (B, S, N); A: (H,) negative.
    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(dt_t * A)  # (B,H)
        xbar = dt_t[..., None] * x_t.astype(jnp.float32)
        state = (state * a[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xbar,
                              B_t.astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), state)
        return state, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1).astype(jnp.float32),
          Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), state
