"""ssd_scan: chunked Mamba-2 SSD Pallas TPU kernel.

KATANA's fused-recursion insight applied to the learned SSM (DESIGN.md
§6): the running (P, N) state lives in VMEM scratch across the whole
sequence sweep — the recurrence never round-trips HBM — while the
intra-chunk work is dense (Q,Q)/(Q,P) MXU matmuls (the "duality" part
of SSD). Grid (B, H, n_chunks), chunk innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, B_ref, C_ref, A_ref, y_ref, state_scr, *,
            chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    Bm = B_ref[0].astype(jnp.float32)        # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)        # (Q, N)
    A = A_ref[0, 0]                          # scalar (this head)

    l = dt * A                               # (Q,) log-decay <= 0
    cum = jnp.cumsum(l)                      # inclusive
    # inter-chunk: y_i += exp(cum_i) * C_i . state
    state = state_scr[...]                   # (P, N)
    y_inter = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]  # (Q,P)
    # intra-chunk: W_ij = (C_i.B_j) exp(cum_i - cum_j) dt_j  (i >= j)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,Q)
    D = jnp.exp(cum[:, None] - cum[None, :])
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    W = jnp.where(mask, G * D * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)
    # state carry: S' = exp(cum_Q) S + x^T (B * exp(cum_Q - cum) dt)
    w_end = jnp.exp(cum[-1] - cum) * dt      # (Q,)
    S_add = jax.lax.dot_general(
        x, Bm * w_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(cum[-1]) + S_add


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bhsp(x, dt, Bm, Cm, A, chunk: int = 256,
                  interpret: bool = True):
    """x: (B, H, S, P); dt: (B, H, S); Bm/Cm: (B, S, N); A: (H, 1).

    Returns y: (B, H, S, P). S % chunk == 0."""
    Bb, H, S, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, A)
