"""Oracle for flash_decode: the XLA decode_attention path."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.attention import KVCache, decode_attention


def flash_decode_ref(q, k_cache, v_cache, k_new, v_new, *, scale: float):
    """Same signature as ops.flash_decode (full-valid cache, no SWA)."""
    H, d = q.shape[2], q.shape[3]
    acfg = AttentionConfig(n_heads=H, n_kv_heads=k_cache.shape[2],
                           head_dim=d, causal=True, softmax_scale=scale)
    return decode_attention(q, KVCache(k_cache, v_cache), k_new, v_new,
                            acfg, valid_len=jnp.asarray(k_cache.shape[1]))
