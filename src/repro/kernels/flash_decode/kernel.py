"""flash_decode: one-token KV-cache attention Pallas TPU kernel.

Grid (B, n_kv_blocks): each program streams its batch-row's cache
through VMEM in (block_k, H, d) tiles, maintaining running max /
denominator / weighted-sum scratch per head. Emits un-normalized
(acc, m, l) so the caller can merge the current token's self-attention
term (and, when the cache is sequence-sharded across chips, so the
partial results merge across shards with the same LSE algebra —
distributed flash-decode, DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr,
            *, scale: float, nk: int):
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (H, d)
    k = k_ref[0].astype(jnp.float32)          # (Bk, H, d)
    v = v_ref[0].astype(jnp.float32)          # (Bk, H, d)
    # s[h, t] = q[h, :] . k[t, h, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale  # (H, Bk)
    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])           # (H, Bk)
    l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=-1)
    m_scr[:, 0] = m_new
    # acc[h, :] += sum_t p[h, t] v[t, h, :]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)   # (H, d)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(jk == nk - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0] = m_scr[...]
        l_ref[0] = l_scr[...]


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_decode_partial(q, k, v, *, scale: float, block_k: int = 1024,
                         interpret: bool = True):
    """q: (B, H, d); k/v: (B, T, H, d) head-broadcast cache.

    Returns un-normalized (acc (B,H,d) f32, m (B,H,1) f32, l (B,H,1)
    f32): out = acc / l after any cross-shard / self-token merge."""
    B, H, d = q.shape
    T = k.shape[1]
    nk = T // block_k
    assert T % block_k == 0, (T, block_k)
    kern = functools.partial(_kernel, scale=scale, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, H, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, H, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, H, d), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, H, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, H, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
