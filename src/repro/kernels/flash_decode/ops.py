"""jit'd wrapper: GQA broadcast + self-token LSE merge in jnp (one
token's worth of algebra; the cache sweep is the kernel's)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_decode.kernel import flash_decode_partial


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, k_new, v_new, *, scale: float,
                 block_k: int = 1024, interpret: bool = True):
    """q/k_new/v_new: (B, 1, H|K, d); cache: (B, T, K, d).

    Returns (B, 1, H, d)."""
    B, _, H, d = q.shape
    K = k_cache.shape[2]
    rep = H // K
    kb = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    vb = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    acc, m, l = flash_decode_partial(q[:, 0], kb, vb, scale=scale,
                                     block_k=block_k, interpret=interpret)
    # merge the current token (self-attention term)
    knb = (jnp.repeat(k_new, rep, axis=2) if rep > 1 else k_new)[:, 0]
    vnb = (jnp.repeat(v_new, rep, axis=2) if rep > 1 else v_new)[:, 0]
    s_self = jnp.einsum("bhd,bhd->bh", q[:, 0].astype(jnp.float32),
                        knb.astype(jnp.float32))[..., None] * scale  # (B,H,1)
    m_tot = jnp.maximum(m, s_self)
    alpha = jnp.exp(m - m_tot)
    e_self = jnp.exp(s_self - m_tot)
    l_tot = l * alpha + e_self
    acc_tot = acc * alpha + e_self * vnb.astype(jnp.float32)
    out = acc_tot / l_tot
    return out[:, None].astype(q.dtype)


def lse_merge(parts):
    """Merge [(acc, m, l), ...] partial results from seq-shards of the
    cache — the distributed flash-decode combiner."""
    accs, ms, ls = zip(*parts)
    m_tot = jnp.max(jnp.stack(ms), axis=0)
    l_tot = sum(l * jnp.exp(m - m_tot) for m, l in zip(ms, ls))
    acc_tot = sum(a * jnp.exp(m - m_tot) for m, a in zip(ms, accs))
    return acc_tot / l_tot
