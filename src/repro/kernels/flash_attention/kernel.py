"""flash_attention: tiled online-softmax attention Pallas TPU kernel.

Grid (BH, nq, nk), kv innermost: the (Bq, Bk) score tile lives in
VMEM/VREGs only — no (S, S) tensor ever reaches HBM, which removes the
dominant memory-roofline term of the XLA fallback (see EXPERIMENTS.md
§Perf). Running max/denominator/accumulator persist in VMEM scratch
across the kv sweep. Causal and sliding-window masks skip fully-masked
tiles via pl.when (compute-term win on top of the memory win).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, nk: int, seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # tile-level mask decisions (static per grid point at run time)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1
                              >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (Bq, d)
        k = k_ref[0].astype(jnp.float32)            # (Bk, d)
        v = v_ref[0].astype(jnp.float32)            # (Bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (Bq, Bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        ok = kpos < seq_k
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[:, 0]                         # (Bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=-1)
        m_scr[:, 0] = m_new
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhsd(q, k, v, *, scale: float, causal: bool = True,
                         window: Optional[int] = None, block_q: int = 512,
                         block_k: int = 512, interpret: bool = True):
    """q: (BH, Sq, d); k/v: (BH, Sk, d) -> (BH, Sq, d).

    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads)."""
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // block_q, Sk // block_k
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, seq_k=Sk)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
