"""Pure-jnp oracle for flash_attention: dense masked softmax attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: Optional[int] = None):
    """q: (BH, Sq, d); k/v: (BH, Sk, d)."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sq, Sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
