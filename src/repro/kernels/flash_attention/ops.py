"""jit'd wrapper: (B, S, H, d) GQA layout in, padding + head broadcast,
custom_vjp with a memory-bounded blockwise backward (forward = Pallas
kernel; backward recomputes per q-block under jax.checkpoint, so neither
direction materializes S x S)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


def _pad_seq(x, block, axis):
    S = x.shape[axis]
    pad = (-S) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, scale: float, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True):
    """q: (B, Sq, H, d); k/v: (B, Sk, H, d) (already GQA-broadcast).

    Returns (B, Sq, H, d)."""
    return _fwd(q, k, v, scale, causal, window, block_q, block_k,
                interpret)[0]


def _fwd(q, k, v, scale, causal, window, block_q, block_k, interpret):
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    qb = _pad_seq(q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d), block_q, 1)
    kb = _pad_seq(k.transpose(0, 2, 1, 3).reshape(B * H, Sk, d), block_k, 1)
    vb = _pad_seq(v.transpose(0, 2, 1, 3).reshape(B * H, Sk, d), block_k, 1)
    o = flash_attention_bhsd(qb, kb, vb, scale=scale, causal=causal,
                             window=window, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    o = o[:, :Sq].reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
    return o, (q, k, v)


def _bwd(scale, causal, window, block_q, block_k, interpret, res, do):
    """Blockwise backward: per q-block dense attention recomputed under
    jax.checkpoint — O(block_q x Sk) transients, never S x S."""
    q, k, v = res
    B, Sq, H, d = q.shape
    bq = min(block_q, Sq)
    nq = max(1, Sq // bq)

    def _block(qq, kk, vv, qpos0):
        s = jnp.einsum("bqhd,bkhd->bhqk", qq.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale
        qpos = qpos0 + jnp.arange(qq.shape[1])[:, None]
        kpos = jnp.arange(kk.shape[1])[None, :]
        ok = jnp.ones(s.shape[-2:], bool)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(vv.dtype), vv)

    blk = jax.checkpoint(_block, static_argnums=())

    def body(carry, i):
        dq, dk, dv = carry
        qb = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, 1)
        dob = jax.lax.dynamic_slice_in_dim(do, i * bq, bq, 1)
        _, vjp = jax.vjp(lambda qq, kk, vv: blk(qq, kk, vv, i * bq),
                         qb, k, v)
        dqb, dkb, dvb = vjp(dob)
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, dqb.astype(q.dtype), i * bq, 1)
        return (dq, dk + dkb.astype(jnp.float32),
                dv + dvb.astype(jnp.float32)), None

    init = (jnp.zeros_like(q),
            jnp.zeros(k.shape, jnp.float32),
            jnp.zeros(v.shape, jnp.float32))
    (dq, dk, dv), _ = jax.lax.scan(body, init, jnp.arange(nq))
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)
