import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e) + roofline measurement artifacts.

For every (arch x shape x mesh) cell:
  1. FULL compile (scan-over-groups): .lower().compile() must succeed;
     memory_analysis() proves per-device residency; wall compile time
     recorded. This is the compile-proof on the production mesh.
  2. COST PROBES (unrolled, depth p and 2p, microbatches=1): FLOPs /
     bytes / collective wire-bytes extrapolated to full depth
     (cost_analysis counts scan bodies once — DESIGN.md §4).
Artifacts land in results/dryrun/<mesh>/<arch>/<shape>.json and are
consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b \
      --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ALL_SHAPES, RunConfig, cell_supported, get_config,
                           get_shape, list_archs)
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_decode_step, make_encode_step,
                                make_prefill_step, make_train_step)
from repro.models import model as model_lib
from repro.models.counting import model_flops
from repro.optim import adamw
from repro.roofline import hlo as hlo_lib
from repro.roofline.analysis import HBM_BW, extrapolate, terms_from
from repro.roofline.memmodel import analytic_bytes_dev
from repro.sharding.rules import make_context

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _default_run(shape, cfg=None) -> RunConfig:
    mb = 8 if shape.kind == "train" else 1
    # big residual streams can't afford selective-remat activation
    # residency at mb=8 (e.g. qwen3: 21 GB/dev of saved qkv/moe hiddens)
    remat = "full" if (cfg is not None and cfg.d_model >= 4096) else "selective"
    return RunConfig(microbatches=mb, remat=remat)


def _lower_cell(cfg, shape, run, ctx):
    """Build (fn, example args with shardings applied via in_shardings)."""
    mesh = ctx.mesh
    bspecs = specs_lib.batch_specs(cfg, shape, run)
    bshard = specs_lib.batch_shardings(cfg, shape, run, ctx)
    if shape.kind == "train":
        astate = adamw.abstract_train_state(
            model_lib.abstract_params(cfg), run.grad_compression)
        sshard = specs_lib.state_shardings(cfg, run, ctx)
        fn = make_train_step(cfg, run, ctx)
        jit = jax.jit(fn, in_shardings=(sshard, bshard),
                      out_shardings=(sshard, None), donate_argnums=(0,))
        return jit, (astate, bspecs)
    aparams = model_lib.abstract_params(cfg)
    pshard = specs_lib.param_shardings(cfg, ctx)
    if shape.kind == "prefill":
        if cfg.is_encoder_only:
            fn = make_encode_step(cfg, ctx)
            jit = jax.jit(fn, in_shardings=(pshard, bshard))
            return jit, (aparams, bspecs)
        fn = make_prefill_step(cfg, ctx)
        cshard = specs_lib.cache_shardings(cfg, shape, ctx)
        jit = jax.jit(fn, in_shardings=(pshard, bshard),
                      out_shardings=(None, cshard))
        return jit, (aparams, bspecs)
    # decode
    acache = specs_lib.cache_specs(cfg, shape)
    cshard = specs_lib.cache_shardings(cfg, shape, ctx)
    fn = make_decode_step(cfg, ctx)
    jit = jax.jit(fn, in_shardings=(pshard, bshard, cshard),
                  out_shardings=(None, cshard), donate_argnums=(2,))
    return jit, (aparams, bspecs, acache)


def _compile_cell(cfg, shape, run, ctx):
    jit, args = _lower_cell(cfg, shape, run, ctx)
    t0 = time.time()
    lowered = jit.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return lowered, compiled, t_lower, t_compile


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4 returns [dict] per device
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    cc = hlo_lib.collective_census(txt)
    tot = hlo_lib.totals(cc)
    return {
        "flops_dev": float(ca.get("flops", 0.0)),
        "bytes_dev": float(ca.get("bytes accessed", 0.0)),
        "coll_wire_bytes_dev": tot["wire_bytes"],
        "coll_wire_bytes_bf16eq_dev": tot["wire_bytes_bf16eq"],
        "coll_operand_bytes_dev": tot["operand_bytes"],
        "coll_count": tot["count"],
    }, cc


def probe_depths(cfg):
    p = cfg.interleave_period()
    return p, 2 * p


def run_cell(arch: str, shape_name: str, mesh_name: str, probes: bool = True,
             run: RunConfig = None, out_root: Path = RESULTS,
             full_compile: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "supported": ok}
    out_dir = out_root / mesh_name / arch
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{shape_name}.json"
    if out_path.exists() and not full_compile:
        # probe-only refresh: keep the existing full-compile record
        old = json.loads(out_path.read_text())
        if "full" in old:
            rec["full"] = old["full"]
    if not ok:
        rec["skip_reason"] = why
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    run = run or _default_run(shape, cfg)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size
    ctx = make_context(
        mesh, fsdp=run.fsdp,
        attn_impl="flash" if run.attn_kernel == "flash" else "auto",
        moe_weight_mode=run.moe_weight_mode)

    if full_compile:
        _, compiled, t_lower, t_compile = _compile_cell(cfg, shape, run, ctx)
        ma = compiled.memory_analysis()
        upcast = hlo_lib.cpu_upcast_bytes(compiled.as_text())
        total_dev = ma.argument_size_in_bytes + ma.temp_size_in_bytes
        corrected = max(ma.argument_size_in_bytes,
                        total_dev - upcast)
        rec["full"] = {
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "arg_bytes_dev": int(ma.argument_size_in_bytes),
            "out_bytes_dev": int(ma.output_size_in_bytes),
            "temp_bytes_dev": int(ma.temp_size_in_bytes),
            "total_bytes_dev": int(total_dev),
            # XLA:CPU legalizes bf16 dots via hoisted f32 converts; TPU
            # runs bf16 natively, so those buffers vanish on the target.
            "cpu_upcast_bytes_dev": int(upcast),
            "total_bytes_dev_tpu_est": int(corrected),
            "fits_16g": total_dev < 16e9,
            "fits_16g_tpu_est": corrected < 16e9,
        }
        cost_full, cc_full = _cost_dict(compiled)
        rec["full"]["cost_scanned"] = cost_full  # NB: scan bodies counted 1x
        del compiled

    if probes:
        p, p2 = probe_depths(cfg)
        prun = dataclasses.replace(run, microbatches=1)
        # probe context: full-einsum attention + unrolled SSD chunk scan
        # so cost_analysis sees every FLOP (inner lax.scan bodies are
        # costed once — DESIGN.md §4); AOT lowering never allocates, so
        # the S^2 score tensor is free here.
        pctx = dataclasses.replace(ctx, attn_impl="full", probe_unroll=True)
        costs = {}
        for L in (p, p2):
            pcfg = dataclasses.replace(cfg, n_layers=L)
            _, compiled, _, tc = _compile_cell(pcfg, shape, prun, pctx)
            costs[L], _ = _cost_dict(compiled)
            costs[L]["compile_s"] = tc
            del compiled
        cost = extrapolate(costs[p], costs[p2], p, cfg.n_layers)
        rec["probe"] = {"p": p, "c_p": costs[p], "c_2p": costs[p2],
                        "extrapolated": cost}
        mf = model_flops(cfg, shape)
        rec["model_flops_total"] = mf
        rec["n_chips"] = n_chips
        ab = analytic_bytes_dev(cfg, shape, run, n_chips,
                                model_size=ctx.model_size)
        rec["analytic_bytes_dev"] = ab
        t = terms_from(cost["flops_dev"], ab, cost["coll_wire_bytes_dev"],
                       model_flops_dev=mf / n_chips)
        rec["roofline"] = {
            "t_compute_s": t.t_compute, "t_memory_s": t.t_memory,
            "t_memory_hlo_upper_s": cost["bytes_dev"] / HBM_BW,
            "t_collective_s": t.t_collective, "dominant": t.dominant,
            "useful_fraction": t.useful_fraction,
            "roofline_fraction": t.roofline_fraction,
        }
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--no-full", action="store_true",
                    help="skip the full-depth compile (probes only)")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--moe-mode", default=None, choices=["gather", "tp2d"],
                    help="override the MoE weight strategy (hillclimb)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None,
                    choices=["none", "selective", "full"])
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate non-MoE weights over the data axes "
                         "(decode serving mode)")
    ap.add_argument("--attn-kernel", default=None, choices=["xla", "flash"])
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES]
              if (args.all or not args.shape) else [args.shape])

    failures = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{mesh_name}/{arch}/{shape_name}"
                t0 = time.time()
                run_override = None
                if (args.moe_mode or args.microbatches or args.remat
                        or args.no_fsdp or args.attn_kernel):
                    base = _default_run(get_shape(shape_name),
                                        get_config(arch))
                    run_override = dataclasses.replace(
                        base,
                        moe_weight_mode=args.moe_mode or base.moe_weight_mode,
                        microbatches=args.microbatches or base.microbatches,
                        remat=args.remat or base.remat,
                        fsdp=not args.no_fsdp,
                        attn_kernel=args.attn_kernel or base.attn_kernel)
                try:
                    rec = run_cell(arch, shape_name, mesh_name,
                                   probes=not args.no_probes,
                                   run=run_override,
                                   out_root=Path(args.out),
                                   full_compile=not args.no_full)
                    if not rec.get("supported", True):
                        print(f"[skip] {tag}: {rec['skip_reason']}")
                        continue
                    dom = rec.get("roofline", {}).get("dominant", "-")
                    fits = rec.get("full", {}).get("fits_16g", "-")
                    print(f"[ok]   {tag}  {time.time()-t0:6.1f}s  "
                          f"dominant={dom} fits16G={fits}", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled")


if __name__ == "__main__":
    main()
