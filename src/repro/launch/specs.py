"""ShapeDtypeStruct input stand-ins + sharding specs for every
(arch x shape) cell — the dry-run contract (weak-type-correct,
shardable, zero device allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import blocks
from repro.models import model as model_lib
from repro.optim import adamw
from repro.sharding.rules import ShardingContext, tree_shardings


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _frontend_len(cfg: ModelConfig, S: int) -> int:
    if cfg.frontend == "audio":
        return S
    return cfg.frontend_positions if cfg.frontend else 0


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Input ShapeDtypeStructs for the cell's step function."""
    B, S = shape.global_batch, shape.seq_len
    nf = _frontend_len(cfg, S)
    st = S - nf
    if shape.kind == "train":
        mb = run.microbatches
        assert B % mb == 0, (B, mb)
        bm = B // mb
        batch = {}
        if nf:
            batch["embeds"] = _sds((mb, bm, nf, cfg.d_model), compute_dtype)
        if st > 0:
            batch["tokens"] = _sds((mb, bm, st), jnp.int32)
        batch["labels"] = _sds((mb, bm, S), jnp.int32)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if nf:
            batch["embeds"] = _sds((B, nf, cfg.d_model), compute_dtype)
        if st > 0:
            batch["tokens"] = _sds((B, st), jnp.int32)
        return batch
    # decode: one new token against a seq_len cache
    return {"token": _sds((B, 1), jnp.int32),
            "cache_pos": _sds((), jnp.int32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                    ctx: ShardingContext) -> Dict[str, Any]:
    mesh = ctx.mesh
    B = shape.global_batch
    dp = ctx.data_axes if B % max(ctx.data_size, 1) == 0 else ()
    bspec = dp if dp else None

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    if shape.kind == "train":
        out = {"labels": ns(None, bspec, None)}
        specs = batch_specs(cfg, shape, run)
        if "tokens" in specs:
            out["tokens"] = ns(None, bspec, None)
        if "embeds" in specs:
            out["embeds"] = ns(None, bspec, None, None)
        return out
    if shape.kind == "prefill":
        out = {}
        specs = batch_specs(cfg, shape, run)
        if "tokens" in specs:
            out["tokens"] = ns(bspec, None)
        if "embeds" in specs:
            out["embeds"] = ns(bspec, None, None)
        return out
    return {"token": ns(bspec, None), "cache_pos": ns()}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Any:
    """Abstract decode-cache pytree for the cell (cache len = seq_len)."""
    return jax.eval_shape(
        lambda: blocks.init_cache(cfg, shape.global_batch, shape.seq_len,
                                  dtype))


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    ctx: ShardingContext) -> Any:
    """KV caches: batch->data when divisible + seq->model (flash-decode
    merge); for B=1 long-context the seq axis takes BOTH data and model
    (fully context-parallel decode). SSM states: batch->data,
    heads->model when divisible. Structure is built from the static
    layer plan, mirroring blocks.init_cache exactly."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMCache, ssm_dims

    mesh = ctx.mesh
    B = shape.global_batch
    b_ok = B % max(ctx.data_size, 1) == 0
    bspec = ctx.data_axes if b_ok else None
    seq_axes = (ctx.model_axis,) if b_ok else ctx.data_axes + (ctx.model_axis,)

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    kv_sh = ns(None, bspec, seq_axes, None, None)  # (G,B,T,K,hd)
    out = {}
    for j, (mix, _) in enumerate(blocks.group_plan(cfg)):
        if mix == "attn":
            out[f"layer{j}"] = KVCache(kv_sh, kv_sh)
        else:
            _, H, _ = ssm_dims(cfg.ssm, cfg.d_model)
            hspec = ctx.model_axis if H % ctx.model_size == 0 else None
            out[f"layer{j}"] = SSMCache(
                state=ns(None, bspec, hspec, None, None),
                conv_x=ns(None, bspec, None, hspec, None),
                conv_B=ns(None, bspec, None, None),
                conv_C=ns(None, bspec, None, None),
            )
    return out


def state_shardings(cfg: ModelConfig, run: RunConfig, ctx: ShardingContext):
    """TrainState shardings: master/m/v/ef shard like the params."""
    aparams = model_lib.abstract_params(cfg)
    pspec = model_lib.param_spec(cfg)
    psh = tree_shardings(pspec, aparams, ctx)
    return adamw.TrainState(
        step=NamedSharding(ctx.mesh, P()),
        master=psh, m=psh, v=psh,
        ef=psh if run.grad_compression else None,
    )


def param_shardings(cfg: ModelConfig, ctx: ShardingContext):
    aparams = model_lib.abstract_params(cfg)
    pspec = model_lib.param_spec(cfg)
    return tree_shardings(pspec, aparams, ctx)
