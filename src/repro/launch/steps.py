"""Jittable step builders: train_step (microbatched grad accumulation,
clipping, optional int8 EF compression, AdamW) and serve steps
(prefill / decode). These are the functions the dry-run lowers and the
launchers drive.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.compression import ef_compress
from repro.models import model as model_lib
from repro.optim import adamw
from repro.sharding.rules import ShardingContext


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    ctx: Optional[ShardingContext] = None,
                    compute_dtype=jnp.bfloat16):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves are shaped (microbatches, mb_batch, ...); gradients are
    accumulated over a lax.scan so activation (and logits) memory is
    bounded by one microbatch while XLA overlaps the per-microbatch
    reduction with the next microbatch's compute.
    """

    def train_step(state: adamw.TrainState, batch: Dict[str, Any]):
        params_c = adamw.compute_params(state, compute_dtype)
        grad_fn = jax.value_and_grad(
            lambda p, mb: model_lib.loss_fn(p, cfg, mb, ctx, run.remat),
            has_aux=True)

        def mb_body(acc, mb):
            gsum, lsum = acc
            (loss, metrics), g = grad_fn(params_c, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss.astype(jnp.float32)), metrics

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_c)
        (gsum, lsum), metrics = jax.lax.scan(
            mb_body, (gzero, jnp.zeros((), jnp.float32)), batch)
        nmb = run.microbatches
        grads = jax.tree.map(lambda g: g / nmb, gsum)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        if run.grad_compression:
            grads, new_ef = ef_compress(grads, state.ef)
            state = state._replace(ef=new_ef)
        lr = adamw.warmup_cosine(state.step, run.learning_rate,
                                 run.warmup_steps, run.total_steps)
        state = adamw.adamw_update(state, grads, lr,
                                   weight_decay=run.weight_decay)
        out_metrics = {
            "loss": lsum / nmb,
            "grad_norm": gnorm,
            "lr": lr,
            "ce": metrics["ce"].mean(),
            "aux": metrics["aux"].mean(),
        }
        return state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[ShardingContext] = None):
    def prefill_step(params, batch):
        logits, caches, _ = model_lib.forward(params, cfg, batch, "prefill",
                                              ctx)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: Optional[ShardingContext] = None):
    def decode_step(params, batch, caches):
        logits, new_caches, _ = model_lib.forward(params, cfg, batch,
                                                  "decode", ctx, caches)
        return logits, new_caches

    return decode_step


def make_encode_step(cfg: ModelConfig, ctx: Optional[ShardingContext] = None):
    """Encoder-only archs (hubert): full-sequence logits, no cache."""

    def encode_step(params, batch):
        x, positions = model_lib._embed_inputs(params, cfg, batch, "prefill")
        if ctx is not None:
            x = ctx.constrain(x)
        from repro.models import blocks, layers as L

        x, _, _ = blocks.stack_apply(params["groups"], x, cfg, "train", ctx,
                                     None, positions, None, remat="none")
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        return model_lib._head(params, cfg, x)

    return encode_step
