"""Serving launcher: the paper's workload — a KATANA tracking engine
fed by batched measurement requests.

  PYTHONPATH=src python -m repro.launch.serve --filter ekf --frames 120
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.filters import get_filter
from repro.core.tracker import TrackerConfig
from repro.data.trajectories import SceneConfig, mot_scene
from repro.serving.engine import TrackingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--filter", default="lkf", choices=["lkf", "ekf"])
    ap.add_argument("--frames", type=int, default=120)
    ap.add_argument("--targets", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model = get_filter(args.filter)
    cfg = TrackerConfig(capacity=args.capacity, max_meas=64)
    scene = SceneConfig(T=args.frames, max_targets=args.targets, max_meas=64)
    z, valid, truth = mot_scene(model, scene, seed=args.seed)
    engine = TrackingEngine(model, cfg)
    n_conf_hist = []
    for t in range(args.frames):
        k = int(valid[t].sum())
        tracks = engine.submit(z[t][valid[t]][:k])
        n_conf_hist.append(len(tracks))
    fps = engine.stats.fps
    print(f"[serve] {args.filter} frames={engine.stats.frames} "
          f"throughput={fps:.1f} FPS "
          f"({1e3 / max(fps, 1e-9):.2f} ms/frame) "
          f"confirmed at end={n_conf_hist[-1]} true={len(truth[-1])}")
    return n_conf_hist


if __name__ == "__main__":
    main()
