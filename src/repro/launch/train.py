"""Training launcher: data pipeline -> microbatched train_step ->
async checkpoints -> crash-restart supervision.

CPU-runnable with --reduced (the quickstart example trains a real loss
curve in minutes); the same driver lowers unchanged onto the production
mesh (the dry-run proves the step compiles there).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --reduced --steps 200 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, available_steps
from repro.configs import RunConfig, get_config, reduced
from repro.data.lm import LMDataPipeline
from repro.launch.steps import make_train_step
from repro.models import model as model_lib
from repro.optim import adamw
from repro.runtime.ft import StragglerDetector, TrainSupervisor
from repro.sharding.rules import ShardingContext


def build(cfg, run: RunConfig, seq_len: int, global_batch: int):
    params = model_lib.init_params(cfg, jax.random.key(run.seed))
    state = adamw.init_train_state(params, run.grad_compression)
    data = LMDataPipeline(cfg.vocab, seq_len, global_batch, seed=run.seed,
                          microbatches=run.microbatches)
    step_fn = jax.jit(make_train_step(cfg, run, ShardingContext(None)),
                      donate_argnums=(0,))
    return state, data, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, n_layers=2, d_model=128, vocab=256, seq=args.seq)
    run = RunConfig(microbatches=args.microbatches, learning_rate=args.lr,
                    warmup_steps=max(10, args.steps // 10),
                    total_steps=args.steps, remat="none",
                    grad_compression=args.grad_compression,
                    checkpoint_every=args.ckpt_every)
    state, data, step_fn = build(cfg, run, args.seq, args.batch)
    mgr = CheckpointManager(args.ckpt_dir, run.keep_checkpoints) \
        if args.ckpt_dir else None

    start = 0
    if args.resume and mgr and available_steps(args.ckpt_dir):
        state, extra = mgr.restore_latest(state)
        data.load_state_dict(extra["data"])
        start = int(extra["step"])
        print(f"[train] resumed from step {start}")

    holder = {"state": state}
    straggler = StragglerDetector(["host0"])
    losses = []

    def one_step(i):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        holder["state"], metrics = step_fn(holder["state"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        straggler.record("host0", time.perf_counter() - t0)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"[train] step={i:5d} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.perf_counter() - t0:.2f}s)", flush=True)
        if mgr and (i + 1) % run.checkpoint_every == 0:
            mgr.save(i + 1, holder["state"],
                     {"step": i + 1, "data": data.state_dict()})

    def restore():
        if not mgr:
            raise RuntimeError("no checkpoint dir: cannot restart")
        holder["state"], extra = mgr.restore_latest(holder["state"])
        data.load_state_dict(extra["data"])
        return int(extra["step"])

    sup = TrainSupervisor(one_step, restore, args.steps)
    report = sup.run(start)
    if mgr:
        mgr.save(args.steps, holder["state"],
                 {"step": args.steps, "data": data.state_dict()},
                 blocking=True)
    print(f"[train] done: {report.steps_run} steps, "
          f"{report.restarts} restarts; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
