"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from repro import compat

try:  # jax >= 0.5 explicit axis types; older releases have neither
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _axis_type_kwargs(n_axes: int):
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data','model') = 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) ('pod','data','model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic restore."""
    return compat.make_mesh(tuple(shape), tuple(axes),
                            **_axis_type_kwargs(len(axes)))
