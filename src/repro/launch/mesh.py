"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data','model') = 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) ('pod','data','model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic restore."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
