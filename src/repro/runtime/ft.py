"""Fault tolerance: heartbeats, straggler detection, crash-restart
supervision.

On a real multi-pod deployment each host runs a heartbeat reporter and
the coordinator holds this logic; here the machinery is host-simulated
(and unit-tested with induced failures) while the state it protects —
checkpoint/restore, data-stream resume, elastic re-shard — is fully
real.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class HeartbeatMonitor:
    """Tracks last-seen timestamps per host; hosts silent for longer
    than `timeout_s` are declared dead."""

    def __init__(self, hosts: List[str], timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        now = clock()
        self.last_seen: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str) -> None:
        self.last_seen[host] = self.clock()

    def remove(self, host: str) -> None:
        """Decommission a host (it was failed over / drained): it must
        stop showing up in ``dead_hosts`` forever after."""
        self.last_seen.pop(host, None)

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_hosts()


class StragglerDetector:
    """Flags hosts whose step time exceeds k x the fleet median (EWMA-
    smoothed). At scale the remediation is re-sharding the straggler's
    slice away or preemptive restart; the detector emits the decision."""

    def __init__(self, hosts: List[str], k: float = 2.0, alpha: float = 0.3):
        self.k = k
        self.alpha = alpha
        self.ewma: Dict[str, Optional[float]] = {h: None for h in hosts}

    def record(self, host: str, step_time_s: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_time_s if prev is None
                           else self.alpha * step_time_s
                           + (1 - self.alpha) * prev)

    def remove(self, host: str) -> None:
        """Drop a decommissioned host from the fleet statistics (its
        stale EWMA must not skew the median for the survivors)."""
        self.ewma.pop(host, None)

    def stragglers(self) -> List[str]:
        vals = [v for v in self.ewma.values() if v is not None]
        if len(vals) < 2:
            return []
        med = sorted(vals)[len(vals) // 2]
        return [h for h, v in self.ewma.items()
                if v is not None and v > self.k * med]


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    restored_steps: List[int] = field(default_factory=list)


class TrainSupervisor:
    """Crash-restart driver around a step function.

    run() executes `step_fn(step_idx)` in a loop; on exception it calls
    `restore_fn()` (which must return the step index to resume from)
    and retries, up to `max_restarts`. Used by launch/train.py and
    exercised with induced failures in tests/test_ft.py.
    """

    def __init__(self, step_fn: Callable[[int], None],
                 restore_fn: Callable[[], int], total_steps: int,
                 max_restarts: int = 3):
        self.step_fn = step_fn
        self.restore_fn = restore_fn
        self.total = total_steps
        self.max_restarts = max_restarts

    def run(self, start_step: int = 0) -> SupervisorReport:
        report = SupervisorReport()
        step = start_step
        while step < self.total:
            try:
                self.step_fn(step)
                step += 1
                report.steps_run += 1
            except Exception:  # noqa: BLE001
                if report.restarts >= self.max_restarts:
                    raise
                report.restarts += 1
                step = self.restore_fn()
                report.restored_steps.append(step)
        return report
