"""int8 error-feedback gradient compression for the DP reduction.

Two artifacts:
  * ``ef_compress`` — the error-feedback quantize/dequantize transform
    applied to the gradient pytree before the optimizer. Numerically
    this is exactly what a compressed DP all-reduce delivers; the
    residual (``ef``) carries the quantization error into the next
    step so the estimator stays unbiased in the long run.
  * ``compressed_psum`` — a real int8 psum for shard_map code paths:
    quantize to int8 with a per-tensor fp32 scale, psum the int8
    payload (32 bits -> 8 bits on the wire, 4x cross-pod traffic
    reduction), psum the tiny scale vector, dequantize. Used by the
    pod-boundary demo in tests/benchmarks and available to
    ``train_step`` via RunConfig.grad_compression.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import compat


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 round-trip on a gradient pytree.

    Returns (decompressed grads, new error residuals)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quantize(g32)
        deq = _dequantize(q, s)
        return deq, g32 - deq

    out = jax.tree.map(leaf, grads, ef)
    deq = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_ef


def compressed_psum(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Ring all-reduce with an int8 wire payload, inside shard_map.

    Each hop ``collective_permute``s the int8 tensor around the ring and
    accumulates in fp32 locally — (P-1) hops of 1-byte elements instead
    of fp32, a 4x cross-pod traffic reduction (the scale scalar is
    shared via one pmax). This is the real compressed collective used
    at the pod boundary; ``ef_compress`` supplies the error feedback.
    """
    P = compat.axis_size(axis)
    smax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis)
    smax = jnp.maximum(smax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / smax), -127, 127
                 ).astype(jnp.int8)
    perm = [(j, (j + 1) % P) for j in range(P)]
    acc = q.astype(jnp.float32)
    buf = q
    for _ in range(P - 1):
        buf = jax.lax.ppermute(buf, axis, perm)
        acc = acc + buf.astype(jnp.float32)
    return acc * smax
