"""Checkpointing: atomic, async, keep-N, elastic restore.

Layout: <dir>/step_<n>/  arrays.npz + manifest.json, committed via
tmp-dir + os.rename (atomic on POSIX). Arrays are saved device-layout-
free (full logical arrays), so restore can re-shard onto ANY mesh —
elastic scaling up/down is a restore-time concern only
(``restore(..., shardings=...)`` device_puts against the new mesh).

Failure contract (the serving/training loops depend on every clause):

* a crash mid-save leaves only a ``.tmp_step_*`` dir — the committed
  steps are never touched, and the next ``save`` (same step or not)
  sweeps stale tmp dirs and still commits atomically;
* ``restore`` validates the manifest's recorded names/shapes/dtypes
  against the ``like`` tree and raises ``CheckpointMismatchError``
  instead of silently unflattening garbage into the wrong structure;
* ``restore(step=None)`` tolerates a concurrent keep-N GC (another
  process or an in-flight async save) deleting the step it just
  listed: it falls back to the next-newest surviving step;
* ``CheckpointManager.save(blocking=True)`` raises save errors
  immediately (not on the next call), async errors surface on the
  next ``save()``/``wait()``; a successful commit is never failed
  retroactively by a keep-N GC hiccup (GC errors warn, they don't
  raise).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
import zipfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointMismatchError(ValueError):
    """The checkpoint's recorded tree (names/shapes/dtypes) does not
    match the ``like`` tree it is being restored into."""


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keyed = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = ["/".join(str(k) for k in path) for path, _ in keyed]
    return list(zip(names, leaves)), treedef


def _sweep_stale_tmp(root: Path) -> None:
    """Remove leftover ``.tmp_step_*`` dirs from crashed saves. Only
    called while no save of OURS is in flight (module ``save`` is
    synchronous; the manager holds one in-flight save and joins it
    first), so anything matching is garbage by construction."""
    for p in root.glob(".tmp_step_*"):
        shutil.rmtree(p, ignore_errors=True)


def save(ckpt_dir: str, step: int, state, extra: Optional[Dict] = None
         ) -> Path:
    """Blocking atomic save of a pytree (+ json-serializable extras)."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}_{os.getpid()}"
    _sweep_stale_tmp(root)  # crashed prior saves (any pid, any step)
    tmp.mkdir(parents=True)
    named, treedef = _flatten(state)
    arrays = {f"a{i}": np.asarray(v) for i, (_, v) in enumerate(named)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "names": [n for n, _ in named],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def available_steps(ckpt_dir: str) -> List[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in root.glob("step_*")
                  if (p / "manifest.json").exists())


def _validate(manifest: Dict, like, leaves) -> None:
    """Names/shapes/dtypes of the checkpoint vs the ``like`` tree.
    ``like`` leaves may be concrete arrays or abstract
    (ShapeDtypeStruct) — anything exposing shape/dtype is checked;
    bare leaves without them only get the name/count check."""
    named, _ = _flatten(like)
    want_names = [n for n, _ in named]
    got_names = manifest["names"]
    if want_names != got_names:
        missing = [n for n in want_names if n not in got_names]
        surplus = [n for n in got_names if n not in want_names]
        raise CheckpointMismatchError(
            f"checkpoint tree does not match `like`: checkpoint has "
            f"{len(got_names)} leaves {got_names[:4]}..., `like` wants "
            f"{len(want_names)} {want_names[:4]}...; missing from "
            f"checkpoint: {missing or 'none'}; not in `like`: "
            f"{surplus or 'none'}")
    shapes = manifest.get("shapes")  # absent in pre-shape manifests
    for i, (name, leaf) in enumerate(named):
        got_dtype = np.dtype(manifest["dtypes"][i])
        got_shape = tuple(shapes[i]) if shapes else np.shape(leaves[i])
        want_dtype = getattr(leaf, "dtype", None)
        want_shape = getattr(leaf, "shape", None)
        if want_dtype is not None and np.dtype(want_dtype) != got_dtype:
            raise CheckpointMismatchError(
                f"leaf '{name}': checkpoint dtype {got_dtype} != `like` "
                f"dtype {np.dtype(want_dtype)}")
        if want_shape is not None and tuple(want_shape) != got_shape:
            raise CheckpointMismatchError(
                f"leaf '{name}': checkpoint shape {got_shape} != `like` "
                f"shape {tuple(want_shape)}")


def _load_step(d: Path, like):
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves = [data[f"a{i}"] for i in range(len(manifest["names"]))]
    _validate(manifest, like, leaves)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def restore(ckpt_dir: str, like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of `like` (a pytree or abstract tree).

    The checkpoint's manifest (names, shapes, dtypes) is validated
    against `like` — a mismatched tree raises
    ``CheckpointMismatchError`` instead of unflattening garbage.

    step=None restores the newest step and falls back to older
    surviving steps if the newest vanishes mid-read (a concurrent
    keep-N GC from another process/thread); an explicit ``step`` never
    falls back.

    shardings: optional matching pytree of NamedSharding — arrays are
    device_put against it (elastic restore onto a different mesh)."""
    explicit = step is not None
    tried: set = set()
    while True:
        steps = [s for s in available_steps(ckpt_dir) if s not in tried]
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        use = step if explicit else steps[-1]
        d = Path(ckpt_dir) / f"step_{use:08d}"
        try:
            restored, manifest = _load_step(d, like)
            break
        except CheckpointMismatchError:
            raise  # a real tree mismatch, not corruption — never retry
        except (FileNotFoundError, zipfile.BadZipFile, KeyError, OSError,
                ValueError):  # ValueError: np.load on a truncated npz
            if explicit:
                raise
            # the step we listed was GC'd (or half-deleted) under us —
            # drop to the next-newest survivor, or give up loudly
            tried.add(use)
            if not [s for s in available_steps(ckpt_dir)
                    if s not in tried]:
                raise
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest["extra"]


class CheckpointManager:
    """Async keep-N manager: save() returns immediately (a background
    thread does the IO + commit + GC); wait() joins outstanding work.
    One in-flight save at a time (the next save waits — backpressure
    beats unbounded queueing on a training loop).

    Error ordering: ``save(blocking=True)`` raises its own failure
    in-call; an async save's failure surfaces on the NEXT ``save()``,
    ``wait()`` or ``restore_latest()`` (whichever comes first, once). A
    keep-N GC failure after a successful commit is a warning, never an
    error — the checkpoint IS on disk."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3):
        self.dir = ckpt_dir
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # a crashed predecessor's tmp dirs are garbage; sweep them so
        # they don't sit next to the committed steps forever
        if Path(ckpt_dir).exists():
            _sweep_stale_tmp(Path(ckpt_dir))

    def save(self, step: int, state, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()  # joins the in-flight save; raises ITS failure here
        # snapshot to host memory synchronously (device buffers may be
        # donated/mutated by the next step)
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            save(self.dir, step, host_state, extra)
            try:
                self._gc()
            except OSError as e:  # committed fine; GC hygiene can wait
                warnings.warn(f"checkpoint GC under {self.dir} failed "
                              f"(step {step} committed): {e!r}",
                              RuntimeWarning, stacklevel=2)

        if blocking:
            work()  # errors raise HERE, not on the next call
            return

        def guarded():
            try:
                work()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=guarded, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like, shardings=None):
        self.wait()  # join in-flight work: no GC can race the listing
        return restore(self.dir, like, shardings=shardings)

    def _gc(self) -> None:
        steps = available_steps(self.dir)
        for s in steps[: -self.keep_n]:
            shutil.rmtree(Path(self.dir) / f"step_{s:08d}",
                          ignore_errors=True)
