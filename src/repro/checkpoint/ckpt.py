"""Checkpointing: atomic, async, keep-N, elastic restore.

Layout: <dir>/step_<n>/  arrays.npz + manifest.json, committed via
tmp-dir + os.rename (atomic on POSIX). Arrays are saved device-layout-
free (full logical arrays), so restore can re-shard onto ANY mesh —
elastic scaling up/down is a restore-time concern only
(``restore(..., shardings=...)`` device_puts against the new mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keyed = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = ["/".join(str(k) for k in path) for path, _ in keyed]
    return list(zip(names, leaves)), treedef


def save(ckpt_dir: str, step: int, state, extra: Optional[Dict] = None
         ) -> Path:
    """Blocking atomic save of a pytree (+ json-serializable extras)."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    named, treedef = _flatten(state)
    arrays = {f"a{i}": np.asarray(v) for i, (_, v) in enumerate(named)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "names": [n for n, _ in named],
        "dtypes": [str(np.asarray(v).dtype) for _, v in named],
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def available_steps(ckpt_dir: str) -> List[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in root.glob("step_*")
                  if (p / "manifest.json").exists())


def restore(ckpt_dir: str, like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of `like` (a pytree or abstract tree).

    shardings: optional matching pytree of NamedSharding — arrays are
    device_put against it (elastic restore onto a different mesh)."""
    steps = available_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves = [data[f"a{i}"] for i in range(len(manifest["names"]))]
    treedef = jax.tree_util.tree_structure(like)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest["extra"]


class CheckpointManager:
    """Async keep-N manager: save() returns immediately (a background
    thread does the IO + commit + GC); wait() joins outstanding work.
    One in-flight save at a time (the next save waits — backpressure
    beats unbounded queueing on a training loop)."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3):
        self.dir = ckpt_dir
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # snapshot to host memory synchronously (device buffers may be
        # donated/mutated by the next step)
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save(self.dir, step, host_state, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like, shardings=None):
        self.wait()
        return restore(self.dir, like, shardings=shardings)

    def _gc(self) -> None:
        steps = available_steps(self.dir)
        for s in steps[: -self.keep_n]:
            shutil.rmtree(Path(self.dir) / f"step_{s:08d}",
                          ignore_errors=True)
