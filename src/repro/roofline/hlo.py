"""Optimized-HLO census: collective ops (+ bytes) and op-category counts.

Works on ``compiled.as_text()`` (post-SPMD, per-device program). Bytes
are computed from the RESULT shape printed on each op line; per-kind
operand/wire bytes are derived using the participant count parsed from
``replica_groups`` (both explicit ``{{0,1,..}}`` and iota
``[g,s]<=[n]`` formats).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-reduce.5 = f32[8,128]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[total]
    return 1


def collective_census(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: count, result_bytes, operand_bytes,
    wire_bytes (ring estimate, per device)."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: dict(count=0, result_bytes=0.0, operand_bytes=0.0,
                     wire_bytes=0.0))
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind, startdone = m.groups()
        if startdone == "-done":
            continue  # counted at -start
        if tuple_body is not None:
            rb = sum(_shape_bytes(t, d)
                     for t, d in _TUPLE_ELEM_RE.findall(tuple_body))
        else:
            rb = _shape_bytes(dtype, dims)
        g = max(2, _group_size(line))
        if kind == "all-gather":
            operand = rb / g
            wire = rb * (g - 1) / g
        elif kind == "all-reduce":
            operand = rb
            wire = 2.0 * rb * (g - 1) / g
        elif kind == "reduce-scatter":
            operand = rb * g
            wire = rb * (g - 1)
        elif kind == "all-to-all":
            operand = rb
            wire = rb * (g - 1) / g
        else:  # collective-permute
            operand = rb
            wire = rb
        d = out[kind]
        d["count"] += 1
        d["result_bytes"] += rb
        d["operand_bytes"] += operand
        d["wire_bytes"] += wire
        # bf16-equivalent wire: XLA:CPU legalizes bf16 dots to f32 and
        # the f32 creeps into the adjacent collectives; the TPU backend
        # keeps them bf16. f32 payloads count at half weight here.
        is_f32 = (tuple_body or "").startswith("f32") or dtype == "f32"
        d["wire_bytes_bf16eq"] = d.get("wire_bytes_bf16eq", 0.0) + (
            wire * 0.5 if is_f32 else wire)
    return dict(out)


def op_census(hlo_text: str, ops=("transpose", "reshape", "gather",
                                  "subtract", "dot", "add", "scatter")
              ) -> Dict[str, int]:
    """Count HLO op kinds (the paper's Fig. 3/4 graph census)."""
    counts = dict.fromkeys(ops, 0)
    pat = re.compile(r"=\s+(?:\([^)]*\)|\w+\[[^\]]*\][^ ]*)\s+([\w-]+)\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if m:
            name = m.group(1)
            if name in counts:
                counts[name] += 1
    return counts


_CONVERT_RE = re.compile(
    r"%\S+ = f32\[([\d,]+)\][^ ]* convert\(")


def cpu_upcast_bytes(hlo_text: str, min_bytes: float = 64e6) -> float:
    """Estimate of XLA:CPU's bf16->f32 dot-operand legalization temps.

    The CPU backend upcasts bf16 GEMM operands to f32 and hoists the
    converts; TPU executes bf16 natively, so these buffers don't exist
    on the target. Sums f32 convert results above the threshold
    (weights/activations feeding dots). Used to report corrected
    per-device temp residency next to the raw number.
    """
    total = 0.0
    for line in hlo_text.splitlines():
        m = _CONVERT_RE.search(line)
        if m:
            n = 1
            for d in m.group(1).split(","):
                n *= int(d)
            b = n * 4
            if b >= min_bytes:
                total += b
    return total


def totals(census: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    t = dict(count=0, result_bytes=0.0, operand_bytes=0.0, wire_bytes=0.0,
             wire_bytes_bf16eq=0.0)
    for d in census.values():
        for k in t:
            t[k] += d.get(k, 0.0)
    return t
