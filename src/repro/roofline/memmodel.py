"""Analytic per-device HBM-traffic model (TPU-fused lower bound).

The CPU-backend HLO is barely fused, so ``cost_analysis()['bytes
accessed']`` counts every elementwise intermediate as HBM traffic — a
~10x overestimate of what a TPU executes (convert/multiply/select
chains fuse into single kernels there). The roofline memory term
therefore uses this analytic model: every tensor that MUST cross HBM on
a fused TPU backend, once per crossing:

  train:   weights in (per microbatch) + grad accum r/w + optimizer
           state r/w + saved activations (remat policy) w+r + logits
           + attention-score passes (XLA fallback materializes S x S)
  prefill: weights + per-layer activations + score passes + cache write
  decode:  weights (active experts only) + full cache read + tiny rest

The HLO-measured value is reported alongside as the unfused upper
bound; DESIGN.md §4 records the methodology.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models.blocks import layer_plan
from repro.models.counting import count_params
from repro.models.ssm import ssm_dims


def _attn_score_bytes(cfg: ModelConfig, B: int, S: int, heads_loc: float,
                      kind: str, attn_kernel: str = "xla") -> float:
    """(B,H,S,S) score-tensor HBM passes for the XLA (non-flash) path.
    3 fwd passes (write scores, softmax r/w, read probs) + 2x on bwd.
    Banded SWA reduces S_k to the window+chunk. The flash kernel keeps
    scores in VMEM: only O(S) LSE stats cross HBM (negligible)."""
    a = cfg.attention
    if a is None or kind == "decode" or attn_kernel == "flash":
        return 0.0
    plan = layer_plan(cfg)
    n_attn = sum(1 for m, _ in plan if m == "attn") * (cfg.n_layers // len(plan))
    s_k = min(S, (a.sliding_window + 1024)) if a.sliding_window else S
    passes = 3.0 if kind == "prefill" else 9.0  # fwd / fwd+bwd+remat
    elem = 4.0  # fp32 scores
    return n_attn * passes * B * heads_loc * S * s_k * elem


def _saved_act_bytes_per_token(cfg: ModelConfig, remat: str) -> float:
    """bf16 bytes saved per token per layer under the remat policy."""
    d = cfg.d_model
    plan = layer_plan(cfg)
    per_layer = []
    for mixer, ffn in plan:
        if remat == "full":
            per_layer.append(d)  # only the layer boundary
            continue
        saved = 2 * d  # layer input + mixer output at the residual
        if mixer == "attn":
            a = cfg.attention
            saved += a.n_heads * a.head_dim + 2 * a.n_kv_heads * a.head_dim
        else:
            d_inner, H, Pd = ssm_dims(cfg.ssm, cfg.d_model)
            saved += 2 * d_inner + 2 * cfg.ssm.d_state + H
        if ffn == "mlp":
            saved += (2 if cfg.act == "swiglu" else 1) * cfg.d_ff + d
        elif ffn == "moe":
            e = cfg.moe
            saved += e.top_k * ((2 if cfg.act == "swiglu" else 1)
                                * e.d_ff_expert) / 4.0 + d  # capacity-bounded
        per_layer.append(saved)
    mean = sum(per_layer) / len(per_layer)
    return mean * 2.0  # bf16


def analytic_bytes_dev(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                       n_chips: int, model_size: int = 16) -> float:
    """Per-device HBM bytes per step (fused lower bound)."""
    B, S = shape.global_batch, shape.seq_len
    total, active = count_params(cfg)
    p_loc = total / n_chips
    data_size = n_chips // model_size
    tokens_dev = B * S / max(data_size, 1) if B >= data_size else B * S
    heads_loc = (cfg.attention.n_heads / model_size
                 if cfg.attention else 0.0)
    b_loc = max(B / data_size, 1.0)

    if shape.kind == "train":
        mb = run.microbatches
        weights = mb * p_loc * 2.0          # bf16 stream per microbatch
        grads = mb * p_loc * 8.0            # fp32 accum r/w per microbatch
        optim = p_loc * 28.0                # master/m/v r/w + grad read
        acts = (tokens_dev * cfg.n_layers
                * _saved_act_bytes_per_token(cfg, run.remat) * 2.0)  # w+r
        logits = tokens_dev * (cfg.vocab / model_size) * 6.0  # bf16 w + f32 r
        scores = _attn_score_bytes(cfg, b_loc * mb, S, heads_loc, "train",
                                   run.attn_kernel)
        return weights + grads + optim + acts + logits + scores
    if shape.kind == "prefill":
        weights = p_loc * 2.0
        acts = (tokens_dev * cfg.n_layers
                * _saved_act_bytes_per_token(cfg, "none"))
        scores = _attn_score_bytes(cfg, b_loc, S, heads_loc, "prefill",
                                   run.attn_kernel)
        cache = _cache_bytes_dev(cfg, shape, n_chips)
        return weights + acts + scores + cache
    # decode: weights (only routed experts) + cache read + write slot
    frac_active = active / total
    touched = p_loc * max(frac_active, min(1.0, B * (cfg.moe.top_k
                          if cfg.moe else 1) / (cfg.moe.num_experts
                          if cfg.moe else 1)))
    cache = _cache_bytes_dev(cfg, shape, n_chips)
    logits = B / max(data_size, 1) * (cfg.vocab / model_size) * 6.0
    return touched * 2.0 + cache + logits


def _cache_bytes_dev(cfg: ModelConfig, shape: ShapeConfig,
                     n_chips: int) -> float:
    """Full decode-cache bytes per device (read once per step)."""
    B, S = shape.global_batch, shape.seq_len
    plan = layer_plan(cfg)
    reps = cfg.n_layers // len(plan)
    total = 0.0
    for mixer, _ in plan:
        if mixer == "attn":
            a = cfg.attention
            T = min(S, a.sliding_window) if a.sliding_window else S
            total += 2 * B * T * a.n_kv_heads * a.head_dim * 2.0
        else:
            d_inner, H, Pd = ssm_dims(cfg.ssm, cfg.d_model)
            total += B * H * Pd * cfg.ssm.d_state * 4.0
    return total * reps / n_chips
