"""Three-term roofline: machine peaks + the compute/memory/collective
time terms.

Originally built for the dry-run artifacts (TPU v5e targets); now also
the model behind ``benchmarks/roofline.py``'s katana-kernel rows, which
compare ``cost_analysis()``-measured FLOPs/bytes of the compiled
programs against the analytic useful-work floor on a per-backend
``Machine``.

Methodology (DESIGN.md §4, calibrated on this container):
  * ``cost_analysis()`` is per-device, post-SPMD.
  * ``lax.scan`` bodies are costed ONCE -> full-depth compiles are used
    for memory/compile-proof only; FLOPs/bytes/collective-bytes come
    from unrolled depth-extrapolation probes:
        per_period = c(2p) - c(p);  total(L) = c(p) + per_period*(L-p)/p
  * Collective bytes use the wire (ring) estimate per device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

# --- TPU v5e per-chip constants (assignment-specified) ---
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link (~)
ICI_LINKS = 4                 # 2D torus: 4 links/chip; effective injection
ICI_BW = ICI_BW_PER_LINK * ICI_LINKS


@dataclass(frozen=True)
class Machine:
    """Per-backend roofline peaks. The cpu entry is an order-of-
    magnitude reference for a few AVX2 cores (enough to classify a
    program compute- vs memory-bound; not a calibrated model of any
    particular host), the tpu_v5e entry the assignment-specified chip."""
    name: str
    peak_flops: float   # FLOP/s
    mem_bw: float       # B/s
    ici_bw: float       # B/s (collective injection; ~0 disables the term)


MACHINES = {
    "tpu_v5e": Machine("tpu_v5e", PEAK_FLOPS_BF16, HBM_BW, ICI_BW),
    "cpu": Machine("cpu", 1.0e11, 2.0e10, 1.0e9),
}


def machine_for_backend(backend: str) -> Machine:
    """Map a jax backend name to its roofline Machine (TPU backends to
    the v5e reference chip, anything unknown to the cpu reference)."""
    if backend.startswith("tpu"):
        return MACHINES["tpu_v5e"]
    return MACHINES.get(backend, MACHINES["cpu"])


@dataclass
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    model_flops_dev: float = 0.0
    peak_flops: float = PEAK_FLOPS_BF16  # the machine the terms used

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops_dev / self.flops_dev if self.flops_dev else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the roofline bound: useful FLOPs / (bound x
        peak). =useful_fraction when compute-bound; lower when memory/
        collective-bound."""
        if self.bound <= 0:
            return 0.0
        return self.model_flops_dev / (self.bound * self.peak_flops)


def terms_from(flops_dev: float, bytes_dev: float, coll_wire_bytes_dev: float,
               model_flops_dev: float = 0.0,
               ici_bw: float = ICI_BW) -> RooflineTerms:
    return RooflineTerms(
        t_compute=flops_dev / PEAK_FLOPS_BF16,
        t_memory=bytes_dev / HBM_BW,
        t_collective=coll_wire_bytes_dev / ici_bw,
        flops_dev=flops_dev, bytes_dev=bytes_dev,
        coll_bytes_dev=coll_wire_bytes_dev,
        model_flops_dev=model_flops_dev,
    )


def terms_on(machine: Machine, flops_dev: float, bytes_dev: float,
             coll_wire_bytes_dev: float = 0.0,
             model_flops_dev: float = 0.0) -> RooflineTerms:
    """``terms_from`` against an explicit ``Machine`` (the katana-kernel
    roofline path; ``terms_from`` keeps the TPU-v5e dry-run contract)."""
    return RooflineTerms(
        t_compute=flops_dev / machine.peak_flops,
        t_memory=bytes_dev / machine.mem_bw,
        t_collective=(coll_wire_bytes_dev / machine.ici_bw
                      if machine.ici_bw else 0.0),
        flops_dev=flops_dev, bytes_dev=bytes_dev,
        coll_bytes_dev=coll_wire_bytes_dev,
        model_flops_dev=model_flops_dev,
        peak_flops=machine.peak_flops,
    )


def extrapolate(c_p: Dict[str, float], c_2p: Dict[str, float], p: int,
                L: int) -> Dict[str, float]:
    """Linear depth extrapolation of a cost dict (keys -> floats)."""
    out = {}
    for k in c_p:
        per_period = c_2p.get(k, 0.0) - c_p[k]
        out[k] = c_p[k] + per_period * (L - p) / p
    return out


def model_flops_total(n_params_active: float, tokens: float,
                      kind: str) -> float:
    """6·N·D for train, 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
