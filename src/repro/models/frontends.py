"""Modality frontends (STUBS per the assignment): the vision/audio
encoders are not part of the assigned backbone; ``input_specs()``
supplies precomputed patch/frame embeddings. A learned projection +
norm adapts them into the residual stream so the adapter still trains.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L


def frontend_init(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    return {
        "proj": (jax.random.normal(key, (d, d)) / np.sqrt(d)).astype(dtype),
        "norm": L._norm_init(d, cfg.norm, dtype),
    }


def frontend_spec(cfg: ModelConfig) -> Dict:
    return {"proj": ("embed", None), "norm": L._norm_spec(cfg.norm)}


def apply_frontend(p: Dict, embeds: jnp.ndarray, cfg: ModelConfig):
    """embeds: (B, T_front, d) precomputed patch/frame features."""
    h = L.apply_norm(p["norm"], embeds, cfg.norm)
    return h @ p["proj"]
