"""Analytic parameter and MODEL_FLOPS accounting (no materialization).

MODEL_FLOPS counts only *algorithmically necessary* work:
  matmul params: 6·N·D train / 2·N·D forward (N = active params)
  attention:     causal-necessary score+value FLOPs (S·S/2, or S·W for
                 sliding-window) — NOT the full-mask S² our XLA fallback
                 executes; the gap shows up in useful_fraction and is
                 exactly what the flash/banded kernels recover.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.blocks import layer_plan
from repro.models.ssm import ssm_dims


def _act_mults(act: str) -> int:
    return 3 if act == "swiglu" else 2


def count_params(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active) parameter counts."""
    d = cfg.d_model
    total = cfg.vocab * d  # embed
    if not cfg.tie_embeddings:
        total += d * cfg.vocab  # head
    if cfg.frontend:
        total += d * d
    active = total
    for mixer, ffn in layer_plan(cfg):
        t = a = 2 * d  # norms
        if mixer == "attn":
            at = cfg.attention
            qkv = d * at.n_heads * at.head_dim + 2 * d * at.n_kv_heads * at.head_dim
            out = at.n_heads * at.head_dim * d
            t += qkv + out
            a += qkv + out
        else:
            s = cfg.ssm
            d_inner, H, Pd = ssm_dims(s, d)
            N = s.d_state
            w = (2 * d * d_inner + 2 * d * N + d * H
                 + s.conv_width * (d_inner + 2 * N)
                 + 3 * H + H * Pd + d_inner * d)
            t += w
            a += w
        if ffn == "mlp":
            m = _act_mults(cfg.act) * d * cfg.d_ff
            t += m
            a += m
        elif ffn == "moe":
            e = cfg.moe
            per = _act_mults(cfg.act) * d * e.d_ff_expert
            t += e.num_experts * per + d * e.num_experts
            a += e.top_k * per + d * e.num_experts
        total += t
        active += a
    return float(total), float(active)


def attention_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    """Causal-necessary attention score+value FLOPs for the whole stack."""
    a = cfg.attention
    if a is None:
        return 0.0
    n_attn = sum(1 for m, _ in layer_plan(cfg)
                 ) if cfg.family != "hybrid" else None
    plan = layer_plan(cfg)
    n_attn = sum(1 for m, _ in plan if m == "attn")
    n_attn *= cfg.n_layers // len(plan)
    hd_total = a.n_heads * a.head_dim
    if kind == "decode":
        # one token against the cache (window-bounded for SWA)
        eff = min(S, a.sliding_window) if a.sliding_window else S
        per_layer = 4.0 * B * eff * hd_total
        mult = 1.0
    else:
        eff = min(S, a.sliding_window) if a.sliding_window else S
        if a.causal and not a.sliding_window:
            eff = S / 2.0
        per_layer = 4.0 * B * S * eff * hd_total
        mult = 3.0 if kind == "train" else 1.0
    return per_layer * n_attn * mult


def ssm_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    """SSD-layer FLOPs: O(1)-state recurrence for decode; for scan modes
    the chunked dual form's intra-chunk matmuls (the algorithm's real
    cost: ~2Q(N + H·P) extra per token at chunk length Q)."""
    if cfg.ssm is None:
        return 0.0
    plan = layer_plan(cfg)
    n_ssm = sum(1 for m, _ in plan if m == "ssm") * (cfg.n_layers // len(plan))
    d_inner, H, Pd = ssm_dims(cfg.ssm, cfg.d_model)
    N = cfg.ssm.d_state
    if kind == "decode":
        per_tok = 6.0 * H * Pd * N
        return per_tok * n_ssm * B
    Q = min(cfg.ssm.chunk, S)
    # per token: state path (6 H P N) + intra-chunk dual matmuls
    # (G: 2QN shared; y_intra: 2Q H P; decay/exp small)
    per_tok = 6.0 * H * Pd * N + 2.0 * Q * N + 2.0 * Q * H * Pd
    mult = 3.0 if kind == "train" else 1.0
    return per_tok * n_ssm * B * S * mult


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Total useful FLOPs for one step of this cell (all devices)."""
    total, active = count_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    head = cfg.d_model * cfg.vocab  # unembedding params
    if shape.kind == "train":
        base = 6.0 * active * B * S
    elif shape.kind == "prefill":
        base = 2.0 * active * B * S
        if not cfg.is_encoder_only:
            # decoder prefill emits logits for the LAST position only
            base -= 2.0 * head * B * (S - 1)
    else:
        base = 2.0 * active * B  # one token
    return (base + attention_flops(cfg, B, S, shape.kind)
            + ssm_flops(cfg, B, S, shape.kind))
