"""Mixture-of-Experts with static-capacity scatter dispatch + expert
parallelism over the mesh ``model`` axis.

Design notes (DESIGN.md §5):
  * Static shapes everywhere (KATANA Opt-2): capacity-bounded buffers,
    token drops instead of dynamic shapes. ``capacity_mode='full'``
    (decode/prefill) sets capacity = local token count — zero drops.
  * Dispatch is a scatter-add into an (E_local, C, d) buffer and a
    gather back — O(T·k·d) bytes, *not* the O(T·E·C·d) one-hot einsum
    dispatch whose FLOPs would rival the expert GEMMs themselves.
  * Expert parallelism via shard_map: each model-shard owns E/TP
    experts; tokens are data-sharded and replicated over `model`; the
    only collective is one psum of the (T_local, d) output over `model`
    (same traffic class as a TP all-reduce).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import MoEConfig


def moe_init(key, cfg: MoEConfig, d: int, act: str, dtype) -> Dict:
    E, f = cfg.num_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (E, f, d)) * s_out).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[3], (E, d, f)) * s_in).astype(dtype)
    return p


def moe_spec(act: str) -> Dict:
    # "moe_d"/"moe_f" resolve per ShardingContext.moe_weight_mode:
    #   gather: moe_d -> FSDP data axes, moe_f -> replicated
    #   tp2d:   moe_d -> replicated,     moe_f -> data axes
    p = {
        "router": (None, None),
        "w_in": ("experts", "moe_d", "moe_f"),
        "w_out": ("experts", "moe_f", "moe_d"),
    }
    if act == "swiglu":
        p["w_gate"] = ("experts", "moe_d", "moe_f")
    return p


def _capacity(cfg: MoEConfig, t_local: int, mode: str) -> int:
    if mode == "full":
        return t_local
    c = int(np.ceil(t_local * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    return max(8, min(t_local, -(-c // 8) * 8))  # 8-aligned, bounded


def _moe_shard(x, p, cfg: MoEConfig, act: str, e_first, e_local: int,
               capacity: int, model_axis: Optional[str]):
    """Per-device MoE: x (T, d) local tokens; expert weights local slices."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue, computed over
    # the flattened (T*k,) routing stream (deterministic, static shapes)
    flat_e = topi.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # position before self
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < capacity

    local_slot = flat_e - e_first
    mine = keep & (local_slot >= 0) & (local_slot < e_local)
    slot_c = jnp.clip(local_slot, 0, e_local - 1)
    pos_c = jnp.clip(flat_pos, 0, capacity - 1)

    tok_idx = jnp.repeat(jnp.arange(T), k)  # (T*k,)
    updates = x[tok_idx] * mine[:, None].astype(x.dtype)
    buf = jnp.zeros((e_local, capacity, d), x.dtype)
    buf = buf.at[slot_c, pos_c].add(updates, mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        h = jax.nn.silu(g) * h
    elif act == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"])  # (E_loc, C, d)

    gathered = y[slot_c, pos_c]  # (T*k, d)
    w = (topw.reshape(-1) * mine.astype(jnp.float32)).astype(x.dtype)
    out = (gathered * w[:, None]).reshape(T, k, d).sum(axis=1)

    # load-balance auxiliary (Switch-style), local shard estimate
    frac = onehot.astype(jnp.float32).mean(axis=0) * k  # fraction routed
    mean_p = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_p) / k

    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out, aux


def apply_moe(p: Dict, x: jnp.ndarray, cfg: MoEConfig, act: str,
              ctx=None, capacity_mode: str = "factor") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (B, S, d), aux-loss scalar.

    ctx: repro.sharding.ShardingContext or None (single-device path).
    """
    B, S, d = x.shape
    if (ctx is None or ctx.mesh is None or ctx.model_size == 1
            or cfg.num_experts % ctx.model_size != 0):
        t_loc = B * S
        cap = _capacity(cfg, t_loc, capacity_mode)
        out, aux = _moe_shard(x.reshape(t_loc, d), p, cfg, act, 0,
                              cfg.num_experts, cap, None)
        return out.reshape(B, S, d), aux

    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    # tokens replicated over model; data-sharded only when divisible
    # (long-context decode runs B=1: tokens replicated everywhere, the
    # parallelism lives in the experts/cache instead)
    dp = ctx.data_axes if B % ctx.data_size == 0 else ()
    tp = ctx.model_axis  # 'model'
    e_local = cfg.num_experts // ctx.model_size
    t_loc = (B // ctx.data_size if dp else B) * S
    cap = _capacity(cfg, t_loc, capacity_mode)

    tp2d = (ctx.moe_weight_mode == "tp2d"
            and cfg.d_ff_expert % ctx.data_size == 0 and ctx.data_size > 1)
    if tp2d:
        return _apply_moe_tp2d(p, x, cfg, act, ctx, capacity_mode)

    # "gather" mode: expert weights are 2D-sharded — experts over
    # `model` AND the embed dim FSDP'd over the data axes (a 398B Jamba
    # or 235B Qwen cannot hold even one expert-shard replicated per data
    # rank). The gather back to full-d happens HERE, explicitly, in bf16
    # — without it the partitioner un-FSDPs outside the shard_map in f32
    # (2x wire + full temps; see EXPERIMENTS.md §Perf log).
    fsdp_moe = ctx.fsdp and d % ctx.data_size == 0 and ctx.data_size > 1
    wspec_in = P(tp, ctx.data_axes if fsdp_moe else None, None)
    wspec_out = P(tp, None, ctx.data_axes if fsdp_moe else None)

    def shard_fn(x_l, router, w_in, w_out, *rest):
        if fsdp_moe:
            w_in = jax.lax.all_gather(w_in, ctx.data_axes, axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, ctx.data_axes, axis=2,
                                       tiled=True)
        pl = {"router": router, "w_in": w_in, "w_out": w_out}
        if rest:
            wg = rest[0]
            if fsdp_moe:
                wg = jax.lax.all_gather(wg, ctx.data_axes, axis=1, tiled=True)
            pl["w_gate"] = wg
        b_l, s_l, _ = x_l.shape
        e_first = jax.lax.axis_index(tp) * e_local
        out, aux = _moe_shard(x_l.reshape(b_l * s_l, d), pl, cfg, act,
                              e_first, e_local, cap, tp)
        # average the aux estimate over data shards
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return out.reshape(b_l, s_l, d), aux

    args = [x, p["router"], p["w_in"], p["w_out"]]
    in_specs = [P(dp if dp else None, None, None), P(None, None),
                wspec_in, wspec_out]
    if "w_gate" in p:
        args.append(p["w_gate"])
        in_specs.append(wspec_in)
    out, aux = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp if dp else None, None, None), P()),
        check=False,  # all_gather over the FSDP axes un-varies the
        # weights; the static VMA checker can't see that.
    )(*args)
    return out, aux


def _apply_moe_tp2d(p: Dict, x: jnp.ndarray, cfg: MoEConfig, act: str,
                    ctx, capacity_mode: str):
    """Decode-optimized MoE: experts over `model` x FFN dim over the
    data axes. ZERO weight movement per step — tokens are replicated
    over the data axes (a few MB at decode batch sizes) and the single
    collective is one psum of the (T, d) output over the whole mesh.
    The win vs "gather" at decode: GB-scale per-layer weight all-gathers
    become MB-scale activation reductions (EXPERIMENTS.md §Perf)."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    mesh = ctx.mesh
    tp = ctx.model_axis
    dpx = ctx.data_axes
    e_local = cfg.num_experts // ctx.model_size
    t_all = B * S
    cap = _capacity(cfg, t_all, capacity_mode)

    def shard_fn(x_l, router, w_in, w_out, *rest):
        # x_l: full tokens (replicated over the mesh); weights:
        # (E_loc, d, f_loc) / (E_loc, f_loc, d)
        pl = {"router": router, "w_in": w_in, "w_out": w_out}
        if rest:
            pl["w_gate"] = rest[0]
        e_first = jax.lax.axis_index(tp) * e_local
        out, aux = _moe_shard(x_l.reshape(t_all, d), pl, cfg, act,
                              e_first, e_local, cap, None)
        # out is partial over BOTH the expert dim (tp) and the FFN-dim
        # contraction (dp): one fused all-reduce completes it.
        out = jax.lax.psum(out, dpx + (tp,))
        return out.reshape(B, S, d), aux

    args = [x, p["router"], p["w_in"], p["w_out"]]
    in_specs = [P(None, None, None), P(None, None),
                P(tp, None, dpx), P(tp, dpx, None)]
    if "w_gate" in p:
        args.append(p["w_gate"])
        in_specs.append(P(tp, None, dpx))
    out, aux = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(P(None, None, None), P()),
        check=False,
    )(*args)
    return out, aux
