"""The full model: embed -> grouped layer stack -> head, with train /
prefill / decode entry points and the CE loss.

Batch dict convention (built by ``repro.launch.specs.input_specs``):
  tokens   (B, S_text) int32          — absent for pure-audio archs
  embeds   (B, T_front, d)            — vlm/audio stub frontends only
  labels   (B, S) int32               — train mode
  token    (B, 1) int32               — decode mode
  cache_pos () int32                  — decode write position
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks, frontends
from repro.models import layers as L
from repro.sharding.rules import ShardingContext


def needs_learned_pos(cfg: ModelConfig) -> bool:
    a = cfg.attention
    return bool(a and not a.use_rope and not cfg.family == "hybrid")


MAX_LEARNED_POS = 32768


def init_params(cfg: ModelConfig, key, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    max_pos = MAX_LEARNED_POS if needs_learned_pos(cfg) else 0
    p: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype, max_pos),
        "groups": blocks.stack_init(ks[1], cfg, dtype),
        "final_norm": L._norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(ks[2], (cfg.d_model, cfg.vocab))
                     / np.sqrt(cfg.d_model)).astype(dtype)
    if cfg.frontend:
        p["frontend"] = frontends.frontend_init(ks[3], cfg, dtype)
    return p


def param_spec(cfg: ModelConfig) -> Dict:
    max_pos = MAX_LEARNED_POS if needs_learned_pos(cfg) else 0
    gspec = blocks.group_spec(cfg)
    # prepend the scanned "layers" axis (never sharded) to every leaf
    gspec = jax.tree.map(
        lambda axes: ("layers",) + axes, gspec,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
    p: Dict[str, Any] = {
        "embed": L.embed_spec(max_pos),
        "groups": gspec,
        "final_norm": L._norm_spec(cfg.norm),
    }
    from repro.configs.base import ModelConfig as _MC  # noqa
    if not cfg.tie_embeddings:
        p["head"] = ("embed", "vocab")
    if cfg.frontend:
        p["frontend"] = frontends.frontend_spec(cfg)
    return p


def abstract_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.key(0))


def _embed_inputs(params, cfg: ModelConfig, batch: Dict, mode: str,
                  pos_offset=0):
    """Returns (x (B,S,d), positions (S,))."""
    parts = []
    if "embeds" in batch:
        fe = frontends.apply_frontend(params["frontend"], batch["embeds"], cfg)
        parts.append(fe)
    key = "token" if mode == "decode" else "tokens"
    if key in batch:
        parts.append(L.apply_embed(params["embed"], batch[key]))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    S = x.shape[1]
    positions = jnp.arange(S) + pos_offset
    if "positions" in params["embed"]:
        table = params["embed"]["positions"]
        pos_emb = jnp.take(table, jnp.clip(positions, 0, table.shape[0] - 1),
                           axis=0)
        x = x + pos_emb
    return x, positions


def _head(params, cfg: ModelConfig, x):
    w = params["embed"]["tokens"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, w)


def forward(params, cfg: ModelConfig, batch: Dict, mode: str,
            ctx: Optional[ShardingContext] = None, caches=None,
            remat: str = "selective"):
    """Returns (logits, new_caches, aux). Decode: S==1 inputs."""
    ctx = ctx or ShardingContext(None)
    cache_pos = batch.get("cache_pos")
    pos_offset = cache_pos if mode == "decode" else 0
    x, positions = _embed_inputs(params, cfg, batch, mode, pos_offset)
    x = ctx.constrain(x)
    x, new_caches, aux = blocks.stack_apply(
        params["groups"], x, cfg, mode, ctx, caches, positions, cache_pos,
        remat=remat if mode == "train" else "none")
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if mode in ("prefill", "decode"):
        x = x[:, -1:]  # only the last position feeds sampling
    logits = _head(params, cfg, x)
    if ctx.mesh is not None:
        bspec = (ctx.data_axes if logits.shape[0] % ctx.data_size == 0
                 else None)
        logits = ctx.constrain(
            logits,
            jax.sharding.PartitionSpec(bspec, None, ctx.model_axis))
    return logits, new_caches, aux


def loss_fn(params, cfg: ModelConfig, batch: Dict,
            ctx: Optional[ShardingContext] = None, remat: str = "selective",
            aux_weight: float = 1e-2, z_weight: float = 1e-4):
    """Mean CE over all positions (+ MoE aux + z-loss). fp32 math."""
    logits, _, aux = forward(params, cfg, batch, "train", ctx, remat=remat)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)  # (B, S)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    zl = jnp.mean(lse * lse)
    total = ce + aux_weight * aux + z_weight * zl
    return total, {"ce": ce, "aux": aux, "z": zl}
