"""Block wiring: per-layer (mixer, ffn) composition and the
scan-over-groups layer stack with configurable remat.

The layer stack is grouped by the arch's interleave period (jamba: 8,
MoE-every-2: 2, uniform: 1) so heterogeneous stacks scan over a
homogeneous group pytree — compile time stays O(period), not O(depth).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache
from repro.models.ssm import SSMCache


def layer_plan(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """[(mixer, ffn)] per layer: mixer in {attn, ssm}; ffn in {mlp, moe,
    none}."""
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    plan = []
    for i in range(cfg.n_layers):
        mixer = kinds[i]
        if cfg.family == "ssm":
            ffn = "none"  # mamba2: the SSD block is the whole layer
        elif moe_mask[i]:
            ffn = "moe"
        else:
            ffn = "mlp" if cfg.d_ff else "none"
        plan.append((mixer, ffn))
    return plan


def group_plan(cfg: ModelConfig) -> List[Tuple[str, str]]:
    p = cfg.interleave_period()
    plan = layer_plan(cfg)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    for g in range(cfg.n_layers // p):
        assert plan[g * p:(g + 1) * p] == plan[:p], "stack not periodic"
    return plan[:p]


def _layer_init(key, cfg: ModelConfig, mixer: str, ffn: str, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L._norm_init(cfg.d_model, cfg.norm, dtype)}
    if mixer == "attn":
        p["attn"] = attn_lib.attn_init(ks[0], cfg.attention, cfg.d_model, dtype)
    else:
        p["ssm"] = ssm_lib.ssm_init(ks[0], cfg.ssm, cfg.d_model, dtype)
    if ffn != "none":
        p["norm2"] = L._norm_init(cfg.d_model, cfg.norm, dtype)
        if ffn == "mlp":
            p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
        else:
            p["moe"] = moe_lib.moe_init(ks[1], cfg.moe, cfg.d_model, cfg.act,
                                        dtype)
    return p


def _layer_spec(cfg: ModelConfig, mixer: str, ffn: str) -> Dict:
    p: Dict[str, Any] = {"norm1": L._norm_spec(cfg.norm)}
    if mixer == "attn":
        p["attn"] = attn_lib.attn_spec(cfg.attention)
    else:
        p["ssm"] = ssm_lib.ssm_spec()
    if ffn != "none":
        p["norm2"] = L._norm_spec(cfg.norm)
        p["mlp" if ffn == "mlp" else "moe"] = (
            L.mlp_spec(cfg.act) if ffn == "mlp" else moe_lib.moe_spec(cfg.act))
    return p


def group_init(key, cfg: ModelConfig, dtype) -> Dict:
    plan = group_plan(cfg)
    ks = jax.random.split(key, len(plan))
    return {f"layer{j}": _layer_init(ks[j], cfg, mix, ffn, dtype)
            for j, (mix, ffn) in enumerate(plan)}


def group_spec(cfg: ModelConfig) -> Dict:
    plan = group_plan(cfg)
    # leading "layers" axis (the scan axis) prepended by stack_spec
    return {f"layer{j}": _layer_spec(cfg, mix, ffn)
            for j, (mix, ffn) in enumerate(plan)}


def _empty_layer_cache(cfg: ModelConfig, mixer: str, B: int, cache_len: int,
                       dtype):
    if mixer == "attn":
        a = cfg.attention
        W = min(cache_len, a.sliding_window) if a.sliding_window else cache_len
        shape = (B, W, a.n_kv_heads, a.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    s = cfg.ssm
    d_inner, H, Pd = ssm_lib.ssm_dims(s, cfg.d_model)
    return SSMCache(
        state=jnp.zeros((B, H, Pd, s.d_state), jnp.float32),
        conv_x=jnp.zeros((B, s.conv_width - 1, H, Pd), dtype),
        conv_B=jnp.zeros((B, s.conv_width - 1, s.d_state), dtype),
        conv_C=jnp.zeros((B, s.conv_width - 1, s.d_state), dtype),
    )


def init_cache(cfg: ModelConfig, B: int, cache_len: int, dtype) -> Dict:
    """Stacked (n_groups, ...) cache pytree for the decode scan."""
    plan = group_plan(cfg)
    n_groups = cfg.n_layers // len(plan)
    one = {f"layer{j}": _empty_layer_cache(cfg, mix, B, cache_len, dtype)
           for j, (mix, _) in enumerate(plan)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), one)


def _layer_apply(p: Dict, x, cfg: ModelConfig, mixer: str, ffn: str,
                 mode: str, ctx, cache, positions, cache_pos):
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    if mixer == "attn":
        out, new_cache = attn_lib.apply_attention(
            p["attn"], h, cfg.attention, positions, mode, cache, cache_pos,
            impl=(ctx.attn_impl if ctx is not None else "auto"), ctx=ctx)
    else:
        out, new_cache = ssm_lib.apply_ssm(
            p["ssm"], h, cfg.ssm, mode, cache,
            unroll=bool(ctx is not None and ctx.probe_unroll))
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        if ffn == "mlp":
            out = L.apply_mlp(p["mlp"], h, cfg.act, ctx=ctx)
        else:
            cap_mode = "factor" if mode == "train" else "full"
            out, aux = moe_lib.apply_moe(p["moe"], h, cfg.moe, cfg.act, ctx,
                                         cap_mode)
        x = x + out
    return x, new_cache, aux


def group_apply(pg: Dict, x, cfg: ModelConfig, mode: str, ctx,
                cache_g: Optional[Dict], positions, cache_pos):
    plan = group_plan(cfg)
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    for j, (mix, ffn) in enumerate(plan):
        name = f"layer{j}"
        c = cache_g.get(name) if cache_g is not None else None
        x, nc, a = _layer_apply(pg[name], x, cfg, mix, ffn, mode, ctx, c,
                                positions, cache_pos)
        if nc is not None:
            new_cache[name] = nc
        aux = aux + a
    return x, new_cache if new_cache else None, aux


def stack_init(key, cfg: ModelConfig, dtype) -> Dict:
    plan = group_plan(cfg)
    n_groups = cfg.n_layers // len(plan)
    keys = jax.random.split(key, n_groups)
    return jax.vmap(lambda k: group_init(k, cfg, dtype))(keys)


def stack_apply(groups: Dict, x, cfg: ModelConfig, mode: str, ctx,
                caches: Optional[Dict], positions, cache_pos,
                remat: str = "selective"):
    """Scan the group stack. Returns (x, new caches | None, aux)."""
    use_cache = mode in ("prefill", "decode")

    def body(carry, inp):
        x, aux = carry
        pg, cg = inp
        x, new_cg, a = group_apply(pg, x, cfg, mode, ctx, cg, positions,
                                   cache_pos)
        return (x, aux + a), new_cg

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "selective":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    n_groups = jax.tree.leaves(groups)[0].shape[0]
    xs = (groups, caches if use_cache else None)
    if n_groups <= 2:
        # Unrolled path: tiny stacks (and the roofline depth-extrapolation
        # probes, which need cost_analysis to see every layer — scan
        # bodies are costed once; see DESIGN.md §4).
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for g in range(n_groups):
            inp = jax.tree.map(lambda t: t[g], xs)
            carry, y = body(carry, inp)
            ys.append(y)
        (x, aux) = carry
        new_caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
                      if use_cache and ys and ys[0] is not None else None)
    else:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, (new_caches if use_cache else None), aux
