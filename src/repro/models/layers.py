"""Shared model layers: norms, embeddings, rotary positions, MLP variants.

Params are plain dicts of jnp arrays. Every initializer has a matching
``*_spec`` returning the same tree with logical-axis tuples, consumed by
``repro.sharding.rules`` to build PartitionSpecs — the KATANA Opt-2
discipline (every layout decided statically, no runtime reshapes).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis names (mapped to mesh axes by repro.sharding.rules):
#   "vocab"   — vocabulary dim            -> model
#   "embed"   — residual-stream dim       -> fsdp data axes (weights)
#   "heads"   — attention head dim        -> model
#   "kv"      — kv-head dim               -> model if divisible
#   "mlp"     — FFN hidden dim            -> model
#   "experts" — MoE expert dim            -> model (EP)
#   "ssm"     — ssm inner-head dim        -> model if divisible
#   null      — replicated

Initializer = jax.nn.initializers.Initializer


def _norm_init(d: int, kind: str, dtype) -> Dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def _norm_spec(kind: str) -> Dict:
    p = {"scale": ("embed_noshard",)}
    if kind == "layernorm":
        p["bias"] = ("embed_noshard",)
    return p


def apply_norm(p: Dict, x: jnp.ndarray, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype, max_pos: int = 0) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {"tokens": (jax.random.normal(k1, (vocab, d)) * 0.02).astype(dtype)}
    if max_pos:
        p["positions"] = (jax.random.normal(k2, (max_pos, d)) * 0.02).astype(dtype)
    return p


def embed_spec(max_pos: int = 0) -> Dict:
    p = {"tokens": ("vocab", "embed")}
    if max_pos:
        p["positions"] = (None, "embed")
    return p


def apply_embed(p: Dict, tokens: jnp.ndarray, positions=None):
    x = jnp.take(p["tokens"], tokens, axis=0)
    if "positions" in p and positions is not None:
        x = x + jnp.take(p["positions"], positions, axis=0)
    return x


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants: swiglu | squared_relu | gelu
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(d_ff)
    p = {
        "w_in": (jax.random.normal(ks[0], (d, d_ff)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(ks[1], (d_ff, d)) * scale_out).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[2], (d, d_ff)) * scale_in).astype(dtype)
    return p


def mlp_spec(act: str) -> Dict:
    p = {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}
    if act == "swiglu":
        p["w_gate"] = ("embed", "mlp")
    return p


def apply_mlp(p: Dict, x: jnp.ndarray, act: str, ctx=None) -> jnp.ndarray:
    def pin_h(h):
        # keep the FFN hidden sharded over `model`: without the pin,
        # XLA's propagation may all-gather the (d, d_ff) weights for
        # small-token matvecs (decode) instead of TP-sharding the GEMM.
        if ctx is None or ctx.mesh is None:
            return h
        if h.shape[-1] % ctx.model_size != 0:
            return h
        from jax.sharding import PartitionSpec as P
        b = ctx.data_axes if h.shape[0] % ctx.data_size == 0 else None
        return ctx.constrain(h, P(b, None, ctx.model_axis))

    h = pin_h(x @ p["w_in"])
    if act == "swiglu":
        h = jax.nn.silu(pin_h(x @ p["w_gate"])) * h
    elif act == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise KeyError(act)
    return h @ p["w_out"]
