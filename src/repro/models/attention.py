"""Attention: GQA/MQA, sliding-window, chunked (memory-bounded) softmax,
and KV-cache decode (including the seq-sharded flash-decode pattern —
the cache is sharded over the sequence axis and GSPMD inserts the
max/sum/weighted-output all-reduces, i.e. the distributed online-softmax
merge).

Layout rules (KATANA Opt-2 discipline): KV is broadcast to the full
query-head count *before* the score einsum so every activation tensor
carries a single `heads` axis that shards cleanly over the mesh `model`
axis; caches are stored un-broadcast at ``n_kv_heads``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig
from repro.models.layers import rope

NEG_INF = -1e30


def attn_init(key, acfg: AttentionConfig, d: int, dtype) -> Dict:
    H, K, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(H * hd)
    p = {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, K, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, K, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * so).astype(dtype),
    }
    if acfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    return p


def attn_spec(acfg: AttentionConfig) -> Dict:
    p = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv", None),
        "wv": ("embed", "kv", None),
        "wo": ("heads", None, "embed"),
    }
    if acfg.qkv_bias:
        p["bq"] = ("heads", None)
        p["bk"] = ("kv", None)
        p["bv"] = ("kv", None)
    return p


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, T, K, hd) — roped keys
    v: jnp.ndarray  # (B, T, K, hd)


def _project_qkv(p: Dict, x: jnp.ndarray, acfg: AttentionConfig,
                 positions: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if acfg.use_rope:
        q = rope(q, positions, acfg.rope_theta)
        k = rope(k, positions, acfg.rope_theta)
    return q, k, v


def _broadcast_kv(t: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, T, K, hd) -> (B, T, H, hd) by repeating each kv head G times."""
    K = t.shape[2]
    if K == n_heads:
        return t
    return jnp.repeat(t, n_heads // K, axis=2)


def _mask_bias(qpos, kpos, causal: bool, window: Optional[int], dtype):
    """Additive bias (…, S_q, S_k) from absolute positions."""
    ok = jnp.ones(qpos.shape[-1:] + kpos.shape[-1:], bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def full_attention(q, k, v, acfg: AttentionConfig, qpos, kpos):
    """Masked softmax attention, full S_q x S_k score tensor.

    q: (B, S, H, hd); k/v: (B, T, K, hd). Used for train-length
    sequences and as the cost-probe reference; long sequences use
    ``chunked_attention``.
    """
    H, hd = acfg.n_heads, acfg.head_dim
    scale = acfg.softmax_scale or 1.0 / np.sqrt(hd)
    kb = _broadcast_kv(k, H)
    vb = _broadcast_kv(v, H)
    scores = jnp.einsum("bshk,bthk->bhst", q, kb).astype(jnp.float32) * scale
    scores = scores + _mask_bias(qpos, kpos, acfg.causal, acfg.sliding_window,
                                 jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthk->bshk", probs, vb)


def chunked_attention(q, k, v, acfg: AttentionConfig, qpos, kpos,
                      q_chunk: int = 1024, kv_chunk: int = 1024):
    """Online-softmax attention, memory O(q_chunk x kv_chunk) — the pure
    JAX mirror of the flash_attention Pallas kernel (kernels/flash_attention
    is the TPU-native version; this is the shardable XLA fallback)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = acfg.softmax_scale or 1.0 / np.sqrt(hd)
    kb = _broadcast_kv(k, H)
    vb = _broadcast_kv(v, H)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq, nk = S // q_chunk, T // kv_chunk
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, T, q_chunk, kv_chunk)
    qc = q.reshape(B, nq, q_chunk, H, hd)
    kc = kb.reshape(B, nk, kv_chunk, H, hd)
    vc = vb.reshape(B, nk, kv_chunk, H, hd)
    qp = qpos.reshape(nq, q_chunk)
    kp = kpos.reshape(nk, kv_chunk)

    def q_block(qi, qpi):
        # qi: (B, q_chunk, H, hd)
        def kv_body(carry, inp):
            m, l, acc = carry
            ki, vi, kpi = inp
            s = jnp.einsum("bqhk,bthk->bhqt", qi, ki).astype(jnp.float32) * scale
            s = s + _mask_bias(qpi, kpi, acfg.causal, acfg.sliding_window,
                               jnp.float32)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pe = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pe.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqt,bthk->bhqk", pe.astype(qi.dtype), vi).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2).astype(q.dtype)  # (B, q_chunk, H, hd)

    out = jax.lax.map(lambda args: q_block(*args),
                      (qc.swapaxes(0, 1), qp))  # (nq, B, q_chunk, H, hd)
    return out.swapaxes(0, 1).reshape(B, S, H, hd)


def swa_attention(q, k, v, acfg: AttentionConfig, qpos, kpos,
                  q_chunk: int = 1024):
    """True banded sliding-window attention: each q chunk attends a
    dynamically-sliced (window + q_chunk) KV band — S·W FLOPs, not S².
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    W = acfg.sliding_window
    band = W + q_chunk
    if T <= band:  # window covers everything: fall back
        return full_attention(q, k, v, acfg, qpos, kpos)
    scale = acfg.softmax_scale or 1.0 / np.sqrt(hd)
    kb = _broadcast_kv(k, H)
    vb = _broadcast_kv(v, H)
    nq = S // q_chunk
    qc = q.reshape(B, nq, q_chunk, H, hd)
    qp = qpos.reshape(nq, q_chunk)

    def q_block(i, qi, qpi):
        start = jnp.clip(i * q_chunk + q_chunk - band, 0, T - band)
        ki = jax.lax.dynamic_slice_in_dim(kb, start, band, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vb, start, band, axis=1)
        kpi = jax.lax.dynamic_slice_in_dim(kpos, start, band, axis=0)
        s = jnp.einsum("bqhk,bthk->bhqt", qi, ki).astype(jnp.float32) * scale
        s = s + _mask_bias(qpi, kpi, acfg.causal, W, jnp.float32)
        probs = jax.nn.softmax(s, axis=-1).astype(qi.dtype)
        o = jnp.einsum("bhqt,bthk->bqhk", probs, vi)
        return o

    out = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.arange(nq), qc.swapaxes(0, 1), qp))
    return out.swapaxes(0, 1).reshape(B, S, H, hd)


def decode_attention(q, cache: KVCache, k_new, v_new, acfg: AttentionConfig,
                     valid_len, ctx=None):
    """One-token attention over a (possibly seq-sharded) KV cache.

    q/k_new/v_new: (B, 1, H|K, hd); cache.k/v: (B, T, K, hd). The cache
    stays sharded over sequence (explicitly constrained — without the
    pins XLA's propagation prefers head sharding and replicates the
    whole cache); the fp32 max / sum / weighted-output reductions are
    then partitioned by GSPMD into the flash-decode all-reduce merge
    (DESIGN.md §5).
    """
    B, T = cache.k.shape[0], cache.k.shape[1]
    H, hd = acfg.n_heads, acfg.head_dim
    scale = acfg.softmax_scale or 1.0 / np.sqrt(hd)

    def pin(x, *spec):
        if ctx is None or ctx.mesh is None:
            return x
        from jax.sharding import PartitionSpec as P
        return ctx.constrain(x, P(*spec))

    bspec, seq_axes = (None, None)
    if ctx is not None and ctx.mesh is not None:
        b_ok = B % ctx.data_size == 0
        bspec = ctx.data_axes if b_ok else None
        seq_axes = ((ctx.model_axis,) if b_ok
                    else ctx.data_axes + (ctx.model_axis,))
    kb = pin(_broadcast_kv(cache.k, H), bspec, seq_axes, None, None)
    vb = pin(_broadcast_kv(cache.v, H), bspec, seq_axes, None, None)
    s_cache = jnp.einsum("bqhk,bthk->bhqt", q, kb).astype(jnp.float32) * scale
    s_cache = pin(s_cache, bspec, None, None, seq_axes)
    idx = jnp.arange(T)
    ok = idx[None, None, None, :] < valid_len
    if acfg.sliding_window:
        ok &= idx[None, None, None, :] >= (valid_len - acfg.sliding_window)
    s_cache = jnp.where(ok, s_cache, NEG_INF)
    s_self = jnp.einsum(
        "bqhk,bqhk->bhq", q, _broadcast_kv(k_new, H)
    ).astype(jnp.float32)[..., None] * scale                      # (B,H,1,1)
    m = jnp.maximum(s_cache.max(axis=-1, keepdims=True), s_self)  # (B,H,1,1)
    e_cache = jnp.exp(s_cache - m)                                # (B,H,1,T)
    e_cache = pin(e_cache, bspec, None, None, seq_axes)
    e_self = jnp.exp(s_self - m)                                  # (B,H,1,1)
    denom = e_cache.sum(axis=-1, keepdims=True) + e_self
    o_cache = jnp.einsum("bhqt,bthk->bhqk", e_cache.astype(q.dtype), vb,
                         preferred_element_type=jnp.float32)
    o_cache = pin(o_cache, bspec, None, None, None)
    v_self = _broadcast_kv(v_new, H).transpose(0, 2, 1, 3)        # (B,H,1,hd)
    out = (o_cache + e_self * v_self.astype(jnp.float32)) / denom
    return out.astype(q.dtype).transpose(0, 2, 1, 3)              # (B,1,H,hd)


def apply_attention(p: Dict, x: jnp.ndarray, acfg: AttentionConfig,
                    positions: jnp.ndarray, mode: str,
                    cache: Optional[KVCache] = None,
                    cache_pos=None, impl: str = "auto",
                    q_chunk: int = 1024,
                    ctx=None) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Unified attention layer.

    mode: "train" | "prefill" | "decode".
      train:   returns (out, None)
      prefill: returns (out, KVCache of the whole sequence — window-
               truncated for SWA archs so the decode cache is bounded)
      decode:  x is (B, 1, d); cache required; cache_pos: scalar ring
               index to write the new KV at; returns (out, new cache)
    impl: auto | full | chunked | swa (train/prefill only)
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, acfg, positions)
    if mode in ("train", "prefill"):
        if impl == "auto":
            if acfg.sliding_window and S > 4 * (acfg.sliding_window + q_chunk):
                impl = "swa"
            elif S > 8192:
                impl = "chunked"
            else:
                impl = "full"
        if impl == "flash":
            # Pallas fused kernel (kernels/flash_attention): scores never
            # reach HBM. interpret=True on CPU; real kernel on TPU.
            from repro.kernels.flash_attention.ops import flash_attention

            scale = acfg.softmax_scale or 1.0 / np.sqrt(acfg.head_dim)
            kb = _broadcast_kv(k, acfg.n_heads)
            vb = _broadcast_kv(v, acfg.n_heads)
            out = flash_attention(q, kb, vb, scale, acfg.causal,
                                  acfg.sliding_window, min(512, S),
                                  min(512, S), True)
        else:
            fn = {"full": full_attention, "chunked": chunked_attention,
                  "swa": swa_attention}[impl]
            out = (fn(q, k, v, acfg, positions, positions) if impl == "full"
                   else fn(q, k, v, acfg, positions, positions,
                           q_chunk=q_chunk))
        new_cache = None
        if mode == "prefill":
            W = acfg.sliding_window
            if W and S > W:
                k_c = k[:, S - W:]
                v_c = v[:, S - W:]
            else:
                k_c, v_c = k, v
            new_cache = KVCache(k_c, v_c)
    else:
        assert cache is not None
        out = decode_attention(q, cache, k, v, acfg,
                               valid_len=jnp.asarray(cache.k.shape[1]),
                               ctx=ctx)
        wpos = cache_pos if cache_pos is not None else cache.k.shape[1] - 1
        W = cache.k.shape[1]
        slot = wpos % W if acfg.sliding_window else jnp.clip(wpos, 0, W - 1)
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
        if ctx is not None and ctx.mesh is not None:
            from jax.sharding import PartitionSpec as P
            B = x.shape[0]
            b_ok = B % ctx.data_size == 0
            bspec = ctx.data_axes if b_ok else None
            seq_axes = ((ctx.model_axis,) if b_ok
                        else ctx.data_axes + (ctx.model_axis,))
            new_k = ctx.constrain(new_k, P(bspec, seq_axes, None, None))
            new_v = ctx.constrain(new_v, P(bspec, seq_axes, None, None))
        new_cache = KVCache(new_k, new_v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache
