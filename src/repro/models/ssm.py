"""Mamba-2 (SSD — state-space duality) block: chunked scan for
train/prefill, O(1)-state step for decode.

The chunked-scan structure is KATANA's insight transplanted (DESIGN.md
§6): a recursive estimator whose per-step algebra is restructured into
dense batched GEMMs, with the running state carried across chunks —
the ``ssd_scan`` Pallas kernel keeps that state VMEM-resident, this
module is the shardable pure-JAX reference.

Projections are stored unfused per stream (z/x/B/C/dt) so each shards
independently: heads on `model` (logical axis "ssm") when divisible,
B/C/dt replicated. The gated output norm is per-head (shard-local).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig


class SSMCache(NamedTuple):
    state: jnp.ndarray   # (B, H, P, N) running SSM state
    conv_x: jnp.ndarray  # (B, w-1, H, P) conv tail for x
    conv_B: jnp.ndarray  # (B, w-1, N)
    conv_C: jnp.ndarray  # (B, w-1, N)


def ssm_dims(cfg: SSMConfig, d: int) -> Tuple[int, int, int]:
    d_inner = cfg.expand * d
    H = d_inner // cfg.head_dim
    return d_inner, H, cfg.head_dim


def ssm_init(key, cfg: SSMConfig, d: int, dtype) -> Dict:
    d_inner, H, Pd = ssm_dims(cfg, d)
    N, w = cfg.d_state, cfg.conv_width
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    dt = jnp.exp(jax.random.uniform(ks[6], (H,),
                 minval=np.log(1e-3), maxval=np.log(1e-1)))
    return {
        "wz": (jax.random.normal(ks[0], (d, H, Pd)) * s).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, H, Pd)) * s).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, N)) * s).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, N)) * s).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, H)) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (w, H, Pd)) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[5], (w, N)) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[5], (w, N)) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": (jnp.log(jnp.expm1(dt))).astype(jnp.float32),
        "norm_scale": jnp.ones((H, Pd), dtype),
        "w_out": (jax.random.normal(ks[7], (H, Pd, d)) /
                  np.sqrt(d_inner)).astype(dtype),
    }


def ssm_spec() -> Dict:
    return {
        "wz": ("embed", "ssm", None),
        "wx": ("embed", "ssm", None),
        "wB": ("embed", None),
        "wC": ("embed", None),
        "wdt": ("embed", "ssm_noshard"),
        "conv_x": (None, "ssm", None),
        "conv_B": (None, None),
        "conv_C": (None, None),
        "A_log": ("ssm_noshard",),
        "D": ("ssm_noshard",),
        "dt_bias": ("ssm_noshard",),
        "norm_scale": ("ssm", None),
        "w_out": ("ssm", None, "embed"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv via shifted adds (width is small/static).

    x: (B, S, ...); w: (width, ...) broadcasting over trailing dims.
    tail: (B, width-1, ...) previous context (decode/chunk continuation).
    """
    width = w.shape[0]
    if tail is None:
        pad = [(0, 0)] * x.ndim
        pad[1] = (width - 1, 0)
        xp = jnp.pad(x, pad)
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    out = sum(xp[:, i:i + S] * w[i] for i in range(width))
    return out


def _per_head_norm(y: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    """Grouped RMSNorm over the head dim P (shard-local)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def ssd_chunked(x, dt, Bm, Cm, A, chunk: int, state0=None,
                unroll: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P) fp-any; dt: (B, S, H) fp32 (post-softplus);
    Bm/Cm: (B, S, N); A: (H,) fp32 negative; state0: (B, H, P, N) or None.
    Returns (y (B, S, H, P), final state (B, H, P, N)).
    """
    Bb, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xc = x.reshape(Bb, nc, Q, H, Pd)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = Bm.reshape(Bb, nc, Q, N)
    Cc = Cm.reshape(Bb, nc, Q, N)

    if state0 is None:
        state0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)

    def chunk_body(S_prev, inp):
        x_c, dt_c, B_c, C_c = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        l = dt_c * A  # (B,Q,H) log-decay, <= 0
        cum = jnp.cumsum(l, axis=1)  # inclusive
        # inter-chunk: contribution of the carried state
        ydec = jnp.exp(cum)  # (B,Q,H)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", C_c, S_prev) * ydec[..., None]
        # intra-chunk: masked decay-weighted (C_i . B_j) x_j dt_j
        G = jnp.einsum("bin,bjn->bij", C_c.astype(jnp.float32),
                       B_c.astype(jnp.float32))  # (B,Q,Q)
        D_ij = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        W = jnp.where(mask[None, :, :, None], G[..., None] * D_ij, 0.0)
        W = W * dt_c[:, None, :, :]  # weight by dt_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", W.astype(x_c.dtype), x_c)
        # state carry to next chunk
        w_end = jnp.exp(cum[:, -1:, :] - cum) * dt_c  # (B,Q,H)
        S_add = jnp.einsum("bqh,bqhp,bqn->bhpn", w_end.astype(jnp.float32),
                           x_c.astype(jnp.float32), B_c.astype(jnp.float32))
        S_new = S_prev * jnp.exp(cum[:, -1, :])[..., None, None] + S_add
        y = y_inter.astype(x_c.dtype) + y_intra
        return S_new, y

    xs = (xc.swapaxes(0, 1), dtc.swapaxes(0, 1), Bc.swapaxes(0, 1),
          Cc.swapaxes(0, 1))
    # unroll=True: cost probes — lax.scan bodies are costed once by
    # cost_analysis, so the roofline probes unroll the chunk loop.
    # Capped at 32 chunks: beyond that the trace blows up compile time
    # and the residual undercount is the SSD share of the remaining
    # chunks (~2% of layer FLOPs for jamba, ~15% for mamba2-130m at
    # 32k — noted in EXPERIMENTS.md §Roofline).
    S_fin, ys = jax.lax.scan(chunk_body, state0, xs,
                             unroll=min(nc, 32) if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(Bb, S, H, Pd)
    return y, S_fin


def apply_ssm(p: Dict, x: jnp.ndarray, cfg: SSMConfig, mode: str,
              cache: Optional[SSMCache] = None, unroll: bool = False
              ) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    """x: (B, S, d). mode: train | prefill | decode (S=1)."""
    B, S, d = x.shape
    d_inner, H, Pd = ssm_dims(cfg, d)
    N, w = cfg.d_state, cfg.conv_width
    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"])
    xs = jnp.einsum("bsd,dhp->bshp", x, p["wx"])
    Bm = x @ p["wB"]  # (B,S,N)
    Cm = x @ p["wC"]
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (H,) negative

    if mode == "decode":
        assert cache is not None and S == 1
        xs_c = _causal_conv(xs, p["conv_x"], cache.conv_x)
        Bm_c = _causal_conv(Bm, p["conv_B"], cache.conv_B)
        Cm_c = _causal_conv(Cm, p["conv_C"], cache.conv_C)
        xs_c, Bm_c, Cm_c = map(jax.nn.silu, (xs_c, Bm_c, Cm_c))
        a = jnp.exp(dt[:, 0] * A)  # (B,H)
        xbar = (dt[:, 0, :, None] * xs_c[:, 0].astype(jnp.float32))  # (B,H,P)
        S_new = (cache.state * a[..., None, None] +
                 jnp.einsum("bhp,bn->bhpn", xbar,
                            Bm_c[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", Cm_c[:, 0].astype(jnp.float32), S_new)
        y = y + p["D"][:, None] * xs_c[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)  # (B,1,H,P)
        new_cache = SSMCache(
            state=S_new,
            conv_x=jnp.concatenate([cache.conv_x[:, 1:], xs], axis=1),
            conv_B=jnp.concatenate([cache.conv_B[:, 1:], Bm], axis=1),
            conv_C=jnp.concatenate([cache.conv_C[:, 1:], Cm], axis=1),
        )
    else:
        xs_c = jax.nn.silu(_causal_conv(xs, p["conv_x"]))
        Bm_c = jax.nn.silu(_causal_conv(Bm, p["conv_B"]))
        Cm_c = jax.nn.silu(_causal_conv(Cm, p["conv_C"]))
        y, S_fin = ssd_chunked(xs_c, dt, Bm_c, Cm_c, A, cfg.chunk,
                               unroll=unroll)
        y = y + (p["D"][:, None] * xs_c.astype(jnp.float32)).astype(y.dtype)
        new_cache = None
        if mode == "prefill":
            new_cache = SSMCache(
                state=S_fin,
                conv_x=xs[:, S - (w - 1):],
                conv_B=Bm[:, S - (w - 1):],
                conv_C=Cm[:, S - (w - 1):],
            )
    y = _per_head_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                       p["norm_scale"])
    out = jnp.einsum("bshp,hpd->bsd", y.astype(x.dtype), p["w_out"])
    return out, new_cache
