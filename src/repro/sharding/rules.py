"""Logical-axis sharding rules: one table maps every logical parameter /
activation axis to mesh axes, for any mesh with ('data','model') or
('pod','data','model') axes. GSPMD-style 2-D weight sharding: TP over
`model`, FSDP over the data axes (toggle via RunConfig.fsdp).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingContext:
    mesh: Optional[Mesh]
    data_axes: Tuple[str, ...] = ("data",)   # DP/FSDP axes ('pod','data')
    model_axis: str = "model"
    fsdp: bool = True
    # attention lowering for train/prefill: auto | full | chunked | swa
    # | flash (Pallas kernel)
    attn_impl: str = "auto"
    # cost-probe mode: unroll inner scans (SSD chunks) so cost_analysis
    # sees every iteration (DESIGN.md §4)
    probe_unroll: bool = False
    # MoE weight strategy: "gather" = FSDP over data axes, gathered
    # per layer (train default — amortized over many tokens);
    # "tp2d" = expert dim over `model` x FFN dim over the data axes —
    # zero weight movement, activation-sized psums instead (decode
    # hillclimb; see EXPERIMENTS.md §Perf).
    moe_weight_mode: str = "gather"

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.model_axis])

    def batch_spec(self, rank: int) -> P:
        """Activations: batch on the data axes, rest replicated."""
        return P(self.data_axes, *([None] * (rank - 1)))

    def constrain(self, x, spec: Optional[P] = None):
        """with_sharding_constraint with a concrete NamedSharding (no
        dependence on an ambient mesh context). Batch dims that don't
        divide the data axes degrade to replication."""
        if self.mesh is None:
            return x
        if spec is None:
            parts = [self.data_axes if x.shape[0] % self.data_size == 0
                     else None] + [None] * (x.ndim - 1)
            spec = P(*parts)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def make_context(mesh: Optional[Mesh], fsdp: bool = True,
                 attn_impl: str = "auto",
                 moe_weight_mode: str = "gather") -> ShardingContext:
    if mesh is None:
        return ShardingContext(None, attn_impl=attn_impl,
                               moe_weight_mode=moe_weight_mode)
    names = mesh.axis_names
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    return ShardingContext(mesh, data_axes, "model", fsdp, attn_impl,
                           moe_weight_mode=moe_weight_mode)


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def logical_to_spec(axes: Sequence[Optional[str]], shape: Tuple[int, ...],
                    ctx: ShardingContext) -> P:
    """Map logical axis names to a PartitionSpec for this mesh.

    Rules:
      vocab / heads / mlp / experts / ssm -> 'model' (if divisible)
      kv        -> 'model' if n_kv divisible by model size, else replicate
      embed     -> FSDP over data axes (if enabled and divisible)
      embed_noshard / None -> replicated
    Divisibility is checked against the actual dim so awkward configs
    (MQA kv=1, 24 ssm heads on a 16-way axis) degrade to replication
    instead of erroring — recorded per-param by ``describe_spec``.
    """
    if ctx.mesh is None:
        return P()
    out = []
    fsdp_used = False
    for name, dim in zip(axes, shape):
        if name in ("vocab", "heads", "mlp", "experts", "ssm"):
            ms = ctx.model_size
            out.append(ctx.model_axis if _divides(dim, ms) else None)
        elif name == "kv":
            ms = ctx.model_size
            out.append(ctx.model_axis if _divides(dim, ms) else None)
        elif name == "embed" and ctx.fsdp and not fsdp_used:
            ds = ctx.data_size
            if _divides(dim, ds):
                out.append(ctx.data_axes)
                fsdp_used = True
            else:
                out.append(None)
        elif name == "moe_d":
            ds = ctx.data_size
            if (ctx.moe_weight_mode == "gather" and ctx.fsdp
                    and not fsdp_used and _divides(dim, ds)):
                out.append(ctx.data_axes)
                fsdp_used = True
            else:
                out.append(None)
        elif name == "moe_f":
            ds = ctx.data_size
            if ctx.moe_weight_mode == "tp2d" and _divides(dim, ds):
                out.append(ctx.data_axes)
            else:
                out.append(None)
        else:
            out.append(None)
    return P(*out)


def sensor_specs(axes_tree, tree, ctx: ShardingContext):
    """PartitionSpec tree for a sensor-stacked tracking bank (or any
    pytree with one 'batch over independent sensors' axis per leaf).

    ``axes_tree`` gives the per-leaf sensor-axis position (see
    ``repro.core.bank.bank_sensor_axes`` — 1 for the model-conditioned
    (K, S, C, ...) leaves of an IMM bank, 0 elsewhere); that axis maps
    to the mesh data axes and everything else is replicated. This is
    the serving analogue of ``logical_to_spec``'s 'embed -> FSDP'
    rule: sensors are the data-parallel unit of the tracking fleet.
    """
    if ctx.mesh is None:
        return jax.tree.map(lambda a, x: P(), axes_tree, tree)

    def one(a, x):
        parts: list = [None] * x.ndim
        parts[a] = ctx.data_axes
        return P(*parts)

    return jax.tree.map(one, axes_tree, tree)


def tree_specs(param_axes, params_shape, ctx: ShardingContext):
    """Map a tree of logical-axes tuples + matching ShapeDtypeStruct tree
    to a tree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes, arr: logical_to_spec(axes, arr.shape, ctx),
        param_axes, params_shape,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def tree_shardings(param_axes, params_shape, ctx: ShardingContext):
    specs = tree_specs(param_axes, params_shape, ctx)
    if ctx.mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs)
