"""Fault-tolerant multi-tenant streaming front end over the fused
tracking engines.

KATANA's premise is a closed control loop: every measurement must be
fused before the next control cycle. At fleet scale that means the
*serving* layer — not the filter math — decides whether the loop
closes: many independent tenants (scenes/sensors) submit frames
asynchronously at different rates, shards die, sensors go dark, and
payloads arrive corrupt, late or duplicated. This module keeps the
loop closed under all of it:

* **Dynamic batch forming** — a ``SlotAllocator`` packs tenants onto
  the padded track/sensor lanes of the vmapped
  ``katana_frame``/``katana_imm_frame`` step (the same per-sensor step
  ``ShardedBankEngine`` serves): each tenant owns one lane of a
  shard's stacked bank, so ONE fused dispatch per shard serves every
  tenant that has a frame pending, and slots on the C axis can never
  be shared between tenants (lanes are disjoint by construction).
  Track ids live in per-tenant namespaces (``ns_base + local id``).
  Lanes whose tenant has nothing pending are *frozen* (their bank
  state is not advanced): a tenant's stream is frame-indexed, so an
  idle pump must not age its tracks.
* **Admission control + backpressure** — bounded per-tenant queues
  with explicit decisions (``Admission``): accept, duplicate-drop,
  deadline-expired shed, drop-oldest replacement, queue-full reject,
  overload reject. Overload never collapses the queues; it walks the
  **degradation ladder** (``ServiceTier``): FULL -> WIDE_GATE (the
  tracker's ``gate_scale`` knob) -> COAST_ONLY (frames served through
  the existing ``valid`` mask with the measurements shed) -> REJECT
  (admission closed). The ladder is monotone in load by construction.
  A ``CircuitBreaker`` guards the dispatch path: repeated failures
  open it (forced REJECT tier) and a half-open probe re-closes it.
* **Checkpointed failover** — every tenant lane is periodically
  snapshotted (``checkpoint.ckpt``: atomic, keep-N, validated
  restore) together with a write-ahead log of the frames applied
  since. When a shard dies (heartbeat timeout via
  ``runtime.ft.HeartbeatMonitor``, or repeated dispatch failures),
  its tenants are restored onto surviving shards: checkpoint restore
  seeds the lane's mode-conditioned (x, P, mu) bitwise, the WAL
  replays through the surviving shard's own fused step, and the
  resumed FrameResult stream is **bitwise-identical** to an
  uninterrupted run (``tests/test_chaos.py`` proves it) with track
  ids preserved.
* **Degraded-input robustness** — NaN/inf payloads coast through the
  tracker's ``nan_guard`` instead of poisoning the bank; a dark
  sensor submits empty frames (tracks coast, then prune); duplicates
  and stale frames are dropped at admission by sequence number.

``serving/faults.py`` injects all of these faults deterministically;
``tests/test_chaos.py`` is the proof suite and ``benchmarks/serving.py``
measures sustained FPS vs offered load and recovery time after a
shard kill (``BENCH_serving.json``).
"""
from __future__ import annotations

import tempfile
import time
import warnings
from collections import deque
from dataclasses import dataclass, field, replace
from enum import Enum, IntEnum
from typing import (Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.core import bank as bank_lib
from repro.core.filters import IMMModel
from repro.core.tracker import (FrameResult, TrackerConfig,
                                make_multi_sensor_step)
from repro.runtime.ft import HeartbeatMonitor, StragglerDetector
from repro.serving.engine import TrackSnapshot

# Per-tenant track-id namespace stride: global id = ns_base + local id.
# 2^20 local ids per tenant epoch is far beyond any bank capacity.
NS_STRIDE = 1 << 20


class ServiceTier(IntEnum):
    """The degradation ladder, ordered: a HIGHER tier is strictly less
    service. More load can only move the tier up (monotone — the
    property tests pin this)."""

    FULL = 0        # measurements served, nominal gate
    WIDE_GATE = 1   # measurements served, gate widened (gate_scale)
    COAST_ONLY = 2  # frames consumed but measurements shed: coast via
                    # the valid mask — cadence kept, quality degraded
    REJECT = 3      # admission closed; queued frames coast-drain


class Admission(Enum):
    """Explicit per-submit decision — backpressure is a return value,
    never an exception and never a silent drop."""

    ACCEPTED = "accepted"
    REPLACED_OLDEST = "replaced-oldest"     # accepted; oldest was shed
    REJECTED_QUEUE_FULL = "rejected-queue-full"
    REJECTED_OVERLOAD = "rejected-overload"  # ladder/breaker at REJECT
    REJECTED_NO_CAPACITY = "rejected-no-capacity"  # no free lane
    DUPLICATE = "duplicate"                 # seq already consumed


@dataclass(frozen=True)
class StreamConfig:
    n_shards: int = 2
    lanes_per_shard: int = 4      # tenant lanes per shard
    queue_depth: int = 4          # bounded per-tenant queue
    checkpoint_every: int = 8     # tenant frames between snapshots
    # degradation-ladder thresholds on the load factor (queued frames /
    # total queue capacity, in [0, 1]); must be sorted ascending
    degrade_at: float = 0.375
    coast_at: float = 0.625
    reject_at: float = 0.875
    wide_gate_scale: float = 2.5  # gate multiplier at WIDE_GATE
    drop_oldest: bool = True      # queue-full: shed oldest, accept new
    # anti-starvation floor: after this many CONSECUTIVE ladder-shed
    # frames a tenant's next frame is served regardless of tier, so a
    # sustained overload degrades everyone instead of starving anyone
    starve_limit: int = 4
    heartbeat_timeout_s: float = 1.0
    breaker_failures: int = 3     # consecutive failures to open
    breaker_cooldown_s: float = 5.0

    def __post_init__(self):
        if not (0.0 < self.degrade_at <= self.coast_at <= self.reject_at):
            raise ValueError("ladder thresholds must be sorted: "
                             f"{self.degrade_at}, {self.coast_at}, "
                             f"{self.reject_at}")


@dataclass(frozen=True)
class DegradationLadder:
    """load in [0, inf) -> ServiceTier; monotone non-decreasing."""

    degrade_at: float
    coast_at: float
    reject_at: float

    def tier_for(self, load: float) -> ServiceTier:
        if load >= self.reject_at:
            return ServiceTier.REJECT
        if load >= self.coast_at:
            return ServiceTier.COAST_ONLY
        if load >= self.degrade_at:
            return ServiceTier.WIDE_GATE
        return ServiceTier.FULL


class CircuitBreaker:
    """Classic three-state breaker around the dispatch path.

    CLOSED: traffic flows, consecutive failures count up. At
    ``failure_threshold`` the breaker OPENs: ``allow()`` is False until
    ``cooldown_s`` elapses, after which it is HALF_OPEN — one probe is
    allowed; its success re-CLOSEs, its failure re-OPENs (fresh
    cooldown). The clock is injectable so chaos tests drive it
    deterministically."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.failures = 0
        self.trips = 0
        self._opened_at: Optional[float] = None

    @property
    def state(self) -> str:
        if self._opened_at is None:
            return self.CLOSED
        if self.clock() - self._opened_at >= self.cooldown_s:
            return self.HALF_OPEN
        return self.OPEN

    def allow(self) -> bool:
        return self.state != self.OPEN

    def record_success(self) -> None:
        self.failures = 0
        self._opened_at = None

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == self.HALF_OPEN or \
                self.failures >= self.failure_threshold:
            self._opened_at = self.clock()  # (re)open, fresh cooldown
            self.trips += 1


class SlotAllocator:
    """Maps tenants onto (shard, lane) slots of the serving fleet.

    Invariants (property-tested): no two tenants ever hold the same
    (shard, lane); the tenant count never exceeds the live lane pool;
    released lanes are reusable; lanes of a dropped (dead) shard are
    never handed out again. Also owns the per-tenant track-id
    namespace counter — a namespace is never reissued, so ids from an
    evicted tenant can never collide with a later one's."""

    def __init__(self, n_shards: int, lanes_per_shard: int):
        self.lanes_per_shard = lanes_per_shard
        # pop() hands out the lowest free lane — deterministic packing
        self.free: Dict[int, List[int]] = {
            s: list(range(lanes_per_shard - 1, -1, -1))
            for s in range(n_shards)}
        self.where: Dict[str, Tuple[int, int]] = {}
        self._next_ns = 0

    def capacity(self) -> int:
        return len(self.where) + sum(len(f) for f in self.free.values())

    def acquire(self, tenant: str,
                prefer: Optional[int] = None) -> Optional[Tuple[int, int]]:
        """Claim a lane for ``tenant`` (must not hold one). Picks the
        shard with the most free lanes (balance), lowest index on
        ties; ``prefer`` pins a shard when it has room. None = full."""
        if tenant in self.where:
            raise ValueError(f"tenant {tenant!r} already holds "
                             f"{self.where[tenant]}")
        if prefer is not None and self.free.get(prefer):
            s = prefer
        else:
            with_room = [(len(f), -s) for s, f in self.free.items() if f]
            if not with_room:
                return None
            s = -max(with_room)[1]
        lane = self.free[s].pop()
        self.where[tenant] = (s, lane)
        return s, lane

    def release(self, tenant: str) -> Tuple[int, int]:
        s, lane = self.where.pop(tenant)
        if s in self.free:  # dead shards are out of the pool
            self.free[s].append(lane)
            self.free[s].sort(reverse=True)
        return s, lane

    def drop_shard(self, shard: int) -> None:
        """A dead shard's lanes leave the pool forever (its tenants
        must be released/re-acquired by the failover path first)."""
        self.free.pop(shard, None)

    def tenants_on(self, shard: int) -> List[str]:
        return sorted(t for t, (s, _) in self.where.items() if s == shard)

    def next_namespace(self) -> int:
        ns = self._next_ns
        self._next_ns += 1
        return ns * NS_STRIDE


@dataclass
class FrameRequest:
    seq: int
    z: np.ndarray               # (k, m), k may be 0 (dark sensor tick)
    t_submit: float
    deadline: Optional[float]   # absolute, front-end clock domain


@dataclass
class TenantUpdate:
    """One applied frame of one tenant's stream."""

    tenant: str
    frame: int                  # tenant-stream frame index (0-based)
    seq: int
    tier: ServiceTier
    kind: str                   # "served" | "coast" | "shed"
    shard: str
    snapshots: List[TrackSnapshot] = field(default_factory=list)


@dataclass
class StreamStats:
    submitted: int = 0
    accepted: int = 0
    duplicates: int = 0
    replaced_oldest: int = 0
    rejected_queue_full: int = 0
    rejected_overload: int = 0
    rejected_no_capacity: int = 0
    expired: int = 0            # deadline-shed before dispatch
    served: int = 0             # frames applied with measurements
    coasted: int = 0            # empty frames applied (dark sensor)
    shed: int = 0               # frames applied coast-only by the ladder
    dispatches: int = 0         # fused step calls
    dispatch_errors: int = 0
    failovers: int = 0          # tenants migrated off dead shards
    shards_lost: int = 0
    checkpoints: int = 0
    parked: int = 0             # tenants with no surviving lane

    @property
    def applied(self) -> int:
        return self.served + self.coasted + self.shed


@dataclass
class _Tenant:
    name: str
    shard: int
    lane: int
    ns_base: int
    ckpt: CheckpointManager
    queue: Deque[FrameRequest] = field(default_factory=deque)
    next_seq: int = 0
    frames_applied: int = 0
    ckpt_frame: int = 0         # frames_applied at the last snapshot
    # write-ahead log since the last checkpoint: (tier, z_row, v_row)
    wal: List[Tuple[int, np.ndarray, np.ndarray]] = field(
        default_factory=list)
    sheds_in_row: int = 0       # consecutive ladder-shed frames
    parked: bool = False


@dataclass
class _Shard:
    name: str
    idx: int
    banks: object               # stacked BankState/IMMBankState, or None
    device: Optional[object] = None
    alive: bool = True          # False once failed over
    killed: bool = False        # fault-injection: silent death
    consecutive_failures: int = 0


# one jitted multi-sensor step per (model, cfg, lane count) — shared by
# every shard and every front end so chaos tests don't recompile per
# fleet (the step closure keeps ``model`` alive, so id() keys are
# stable)
_STEP_CACHE: Dict[Tuple, Tuple] = {}


def _multi_step(model, cfg: TrackerConfig, lanes: int):
    key = (id(model), cfg, lanes)
    if key not in _STEP_CACHE:
        one, axes, step = make_multi_sensor_step(model, cfg)
        _STEP_CACHE[key] = (one, axes, jax.jit(step), model)
    return _STEP_CACHE[key][:3]


def _select_lanes(mask: np.ndarray, new, old, axes):
    """Per-lane select over a stacked bank: lane i takes ``new`` where
    mask[i], else keeps ``old`` — how idle tenants' lanes are frozen
    while the dispatch still runs as one fused call."""
    m = jnp.asarray(mask)

    def sel(n, o, a):
        shape = (1,) * a + (m.shape[0],) + (1,) * (n.ndim - a - 1)
        return jnp.where(m.reshape(shape), n, o)

    return jax.tree.map(sel, new, old, axes)


class StreamFrontEnd:
    """The multi-tenant streaming facade over the fused frame step.

    ``attach`` a tenant, ``submit`` its frames (any rate, any order —
    admission answers with an explicit decision), ``pump`` once per
    serving cycle: one fused vmapped dispatch per live shard serves
    every tenant with a frame pending and returns the per-tenant
    ``TenantUpdate``s. ``kill_shard`` is the fault-injection surface;
    recovery (checkpoint restore + WAL replay onto a surviving shard)
    happens inside ``pump`` once the heartbeat monitor declares the
    shard dead.

    The ``clock`` is injectable (deadlines, heartbeats and the circuit
    breaker all read it) so every failure path is deterministic under
    test; wall-time dispatch statistics always use
    ``time.perf_counter``.
    """

    def __init__(self, model, cfg: Optional[StreamConfig] = None,
                 tracker: Optional[TrackerConfig] = None,
                 ckpt_dir: Optional[str] = None,
                 clock: Callable[[], float] = time.monotonic,
                 devices: Optional[Sequence] = None):
        self.model = model
        self.cfg = cfg or StreamConfig()
        self.tracker = tracker or TrackerConfig(capacity=64, max_meas=32)
        self.is_imm = isinstance(model, IMMModel)
        self.clock = clock
        self.ckpt_root = ckpt_dir or tempfile.mkdtemp(
            prefix="katana_stream_ckpt_")
        self.ladder = DegradationLadder(self.cfg.degrade_at,
                                        self.cfg.coast_at,
                                        self.cfg.reject_at)
        self.breaker = CircuitBreaker(self.cfg.breaker_failures,
                                      self.cfg.breaker_cooldown_s, clock)
        self.alloc = SlotAllocator(self.cfg.n_shards,
                                   self.cfg.lanes_per_shard)
        self.stats = StreamStats()
        self.tenants: Dict[str, _Tenant] = {}
        self._tier_cfg = {
            ServiceTier.FULL: self.tracker,
            ServiceTier.WIDE_GATE: replace(
                self.tracker,
                gate_scale=self.tracker.gate_scale
                * self.cfg.wide_gate_scale),
        }
        L = self.cfg.lanes_per_shard
        one, axes, _ = _multi_step(model, self.tracker, L)
        self._one, self._axes = one, axes
        devs = list(devices) if devices is not None else jax.devices()
        self.shards: List[_Shard] = []
        for s in range(self.cfg.n_shards):
            banks = bank_lib.stack_sensor_banks(one, L)
            dev = devs[s % len(devs)] if devs else None
            if dev is not None:
                banks = jax.device_put(banks, dev)
            self.shards.append(_Shard(f"shard{s}", s, banks, device=dev))
        self.monitor = HeartbeatMonitor([sh.name for sh in self.shards],
                                        self.cfg.heartbeat_timeout_s,
                                        clock)
        self.stragglers = StragglerDetector([sh.name for sh in self.shards])

    # ------------------------------------------------------------ admission
    def attach(self, tenant: str) -> Admission:
        """Admit a tenant: claim a lane, reset it to an empty bank, and
        write its frame-0 checkpoint (failover must always have a
        snapshot to restore from)."""
        if tenant in self.tenants:
            raise ValueError(f"tenant {tenant!r} already attached")
        alive = {sh.idx for sh in self.shards if sh.alive}
        while True:
            loc = self.alloc.acquire(tenant)
            if loc is None or loc[0] in alive:
                break
            # allocator still had room only on a dead shard
            self.alloc.release(tenant)
            self.alloc.drop_shard(loc[0])
        if loc is None:
            self.stats.rejected_no_capacity += 1
            return Admission.REJECTED_NO_CAPACITY
        s, lane = loc
        shard = self.shards[s]
        shard.banks = bank_lib.place_sensor_bank(shard.banks, lane,
                                                 self._one)
        t = _Tenant(tenant, s, lane, self.alloc.next_namespace(),
                    CheckpointManager(f"{self.ckpt_root}/{tenant}",
                                      keep_n=2))
        self.tenants[tenant] = t
        self._checkpoint(t)
        return Admission.ACCEPTED

    def detach(self, tenant: str) -> None:
        t = self.tenants.pop(tenant)
        if not t.parked:
            self.alloc.release(tenant)

    def submit(self, tenant: str, z, seq: Optional[int] = None,
               deadline: Optional[float] = None) -> Admission:
        """Queue one frame for ``tenant``. z: (k, m) measurements (k=0
        = dark-sensor tick: the frame coasts). ``seq`` defaults to the
        next expected; anything already consumed is a DUPLICATE (late
        and re-sent frames alike). ``deadline`` is absolute on the
        front-end clock; expired frames are shed before dispatch."""
        t = self.tenants[tenant]
        self.stats.submitted += 1
        z = np.asarray(z, np.float32).reshape(-1, self.model.m)
        seq = t.next_seq if seq is None else int(seq)
        if seq < t.next_seq:
            self.stats.duplicates += 1
            return Admission.DUPLICATE
        if self.effective_tier() >= ServiceTier.REJECT:
            self.stats.rejected_overload += 1
            return Admission.REJECTED_OVERLOAD
        req = FrameRequest(seq, z, self.clock(), deadline)
        decision = Admission.ACCEPTED
        if len(t.queue) >= self.cfg.queue_depth:
            if not self.cfg.drop_oldest:
                self.stats.rejected_queue_full += 1
                return Admission.REJECTED_QUEUE_FULL
            t.queue.popleft()  # stalest frame is the cheapest to lose
            self.stats.replaced_oldest += 1
            decision = Admission.REPLACED_OLDEST
        t.queue.append(req)
        t.next_seq = seq + 1
        self.stats.accepted += 1
        return decision

    # ------------------------------------------------------------- telemetry
    def pending(self) -> int:
        return sum(len(t.queue) for t in self.tenants.values())

    def load(self) -> float:
        cap = max(1, len(self.tenants)) * self.cfg.queue_depth
        return self.pending() / cap

    def effective_tier(self) -> ServiceTier:
        """Ladder tier from the current load, forced to REJECT while
        the circuit breaker is open."""
        tier = self.ladder.tier_for(self.load())
        if not self.breaker.allow():
            return ServiceTier.REJECT
        return tier

    def shards_alive(self) -> List[str]:
        return [sh.name for sh in self.shards if sh.alive]

    # ------------------------------------------------------------ fault hook
    def kill_shard(self, shard) -> None:
        """Fault injection: the shard dies silently — it stops serving
        and stops heartbeating, but the front end only learns of it
        when the heartbeat times out (or dispatches keep failing)."""
        sh = self._shard(shard)
        sh.killed = True
        sh.banks = None  # the state is gone with the host

    def _shard(self, shard) -> _Shard:
        if isinstance(shard, _Shard):
            return shard
        for sh in self.shards:
            if sh.idx == shard or sh.name == shard:
                return sh
        raise KeyError(shard)

    # ---------------------------------------------------------------- pump
    def pump(self) -> Dict[str, TenantUpdate]:
        """One serving cycle: detect/recover dead shards, then one
        fused dispatch per live shard over every tenant with a pending
        frame. Returns the applied updates keyed by tenant. Never
        raises on shard failure — errors feed the breaker and the
        failover path."""
        now = self.clock()
        # a reachable shard beats once per pump; a killed one goes
        # silent and crosses the timeout after enough clock passes
        for sh in self.shards:
            if sh.alive and not sh.killed:
                self.monitor.beat(sh.name)
        self._recover_dead(now)
        tier = self.effective_tier()
        updates: Dict[str, TenantUpdate] = {}
        for sh in self.shards:
            if not sh.alive:
                continue
            self._pump_shard(sh, tier, now, updates)
        return updates

    def _pump_shard(self, sh: _Shard, tier: ServiceTier, now: float,
                    updates: Dict[str, TenantUpdate]) -> None:
        L, M, m = (self.cfg.lanes_per_shard, self.tracker.max_meas,
                   self.model.m)
        zb = np.zeros((L, M, m), np.float32)
        vb = np.zeros((L, M), bool)
        participate = np.zeros((L,), bool)
        plan: List[Tuple[_Tenant, FrameRequest, str]] = []
        for name in self.alloc.tenants_on(sh.idx):
            t = self.tenants[name]
            while t.queue and t.queue[0].deadline is not None \
                    and t.queue[0].deadline < now:
                t.queue.popleft()
                self.stats.expired += 1
            if not t.queue:
                continue  # lane frozen this pump
            req = t.queue[0]  # peek — committed only if dispatch lands
            k = min(len(req.z), M)
            starving = t.sheds_in_row >= self.cfg.starve_limit - 1
            if tier >= ServiceTier.COAST_ONLY and k and not starving:
                kind = "shed"  # ladder sheds the measurements, keeps
                # the cadence: the lane coasts via the valid mask
            elif k == 0:
                kind = "coast"
            else:
                # nominal service — or the anti-starvation floor firing
                # under a coasting tier
                kind = "served"
                zb[t.lane, :k] = req.z[:k]
                vb[t.lane, :k] = True
            participate[t.lane] = True
            plan.append((t, req, kind))
        if sh.killed or not plan:
            return  # dead: no result, queues intact; idle: lanes frozen
        step_tier = (ServiceTier.WIDE_GATE if tier == ServiceTier.WIDE_GATE
                     else ServiceTier.FULL)
        t0 = time.perf_counter()
        try:
            res = self._step_for(step_tier)(sh.banks, jnp.asarray(zb),
                                            jnp.asarray(vb))
            jax.block_until_ready(res.bank.x)
        except Exception:  # noqa: BLE001 — the loop must keep closing
            self.stats.dispatch_errors += 1
            self.breaker.record_failure()
            sh.consecutive_failures += 1
            if sh.consecutive_failures >= self.cfg.breaker_failures:
                sh.killed = True  # persistent failure == dead shard
                sh.banks = None
            return
        dt = time.perf_counter() - t0
        sh.consecutive_failures = 0
        self.breaker.record_success()
        self.stragglers.record(sh.name, dt)
        self.stats.dispatches += 1
        sh.banks = _select_lanes(participate, res.bank, sh.banks,
                                 self._axes)
        counters = {"served": "served", "coast": "coasted", "shed": "shed"}
        for t, req, kind in plan:
            t.queue.popleft()  # commit
            # the WAL records the step tier that actually dispatched —
            # replay re-runs exactly that step, which is what makes the
            # resumed stream bitwise
            t.wal.append((int(step_tier), zb[t.lane].copy(),
                          vb[t.lane].copy()))
            frame = t.frames_applied
            t.frames_applied += 1
            t.sheds_in_row = t.sheds_in_row + 1 if kind == "shed" else 0
            field_name = counters[kind]
            setattr(self.stats, field_name,
                    getattr(self.stats, field_name) + 1)
            updates[t.name] = TenantUpdate(
                t.name, frame, req.seq, tier, kind, sh.name,
                self._lane_snapshots(res, t.lane, t.ns_base))
            if t.frames_applied - t.ckpt_frame >= self.cfg.checkpoint_every:
                self._checkpoint(t)

    def _step_for(self, tier: ServiceTier):
        cfg = self._tier_cfg[tier]
        _, _, step = _multi_step(self.model, cfg,
                                 self.cfg.lanes_per_shard)
        return step

    def _lane_snapshots(self, res: FrameResult, lane: int,
                        ns_base: int) -> List[TrackSnapshot]:
        conf = np.asarray(res.confirmed)[lane]
        idx = np.nonzero(conf)[0]
        if not len(idx):
            return []
        bank = res.bank
        ids = np.asarray(bank.track_id)[lane]
        hits = np.asarray(bank.hits)[lane]
        age = np.asarray(bank.age)[lane]
        if self.is_imm:
            xs = np.asarray(res.x_est)[lane]
            mus = np.asarray(res.mode_probs)[lane]
        else:
            xs, mus = np.asarray(bank.x)[lane], None
        return [TrackSnapshot(ns_base + int(ids[i]), xs[i].copy(),
                              int(hits[i]), int(age[i]),
                              mus[i].copy() if mus is not None else None)
                for i in idx]

    # ----------------------------------------------------------- checkpoint
    def _checkpoint(self, t: _Tenant) -> None:
        sh = self.shards[t.shard]
        lane_bank = bank_lib.slice_sensor_bank(sh.banks, t.lane)
        try:
            t.ckpt.save(t.frames_applied, lane_bank,
                        extra=dict(tenant=t.name, frame=t.frames_applied,
                                   ns_base=t.ns_base,
                                   next_seq=t.next_seq),
                        blocking=True)
        except OSError as e:
            # keep the WAL — failover replays from the older snapshot
            warnings.warn(f"checkpoint for tenant {t.name!r} at frame "
                          f"{t.frames_applied} failed ({e!r}); WAL "
                          f"retained back to frame {t.ckpt_frame}",
                          RuntimeWarning, stacklevel=2)
            return
        t.ckpt_frame = t.frames_applied
        t.wal.clear()
        self.stats.checkpoints += 1

    # ------------------------------------------------------------- failover
    def _recover_dead(self, now: float) -> None:
        for name in self.monitor.dead_hosts():
            self._failover(self._shard(name))

    def _failover(self, sh: _Shard) -> None:
        """The dead shard's tenants restore onto survivors: checkpoint
        seeds the lane bitwise (mode-conditioned x/P/mu, lifecycle,
        ids), the WAL replays the frames applied since through the
        SURVIVING shard's own fused step (lanes are independent, so a
        scratch dispatch reproduces the lane bit-for-bit), and the
        tenant resumes where it left off — same track ids, same
        stream."""
        sh.alive = False
        self.stats.shards_lost += 1
        moved = self.alloc.tenants_on(sh.idx)
        for name in moved:
            self.alloc.release(name)
        self.alloc.drop_shard(sh.idx)
        self.monitor.remove(sh.name)
        self.stragglers.remove(sh.name)
        sh.banks = None
        for name in moved:
            t = self.tenants[name]
            loc = None
            alive = {s.idx for s in self.shards if s.alive}
            while True:
                loc = self.alloc.acquire(name)
                if loc is None or loc[0] in alive:
                    break
                self.alloc.release(name)
                self.alloc.drop_shard(loc[0])
            if loc is None:
                t.parked = True
                self.stats.parked += 1
                warnings.warn(f"tenant {name!r} parked: no surviving "
                              f"lane to restore onto", RuntimeWarning,
                              stacklevel=2)
                continue
            self._restore_tenant(t, *loc)
            self.stats.failovers += 1

    def _restore_tenant(self, t: _Tenant, s: int, lane: int) -> None:
        target = self.shards[s]
        state, extra = t.ckpt.restore_latest(like=self._one)
        if extra["frame"] + len(t.wal) != t.frames_applied:
            warnings.warn(
                f"tenant {t.name!r}: WAL covers frames "
                f"{extra['frame']}..{extra['frame'] + len(t.wal)} but "
                f"{t.frames_applied} were applied — resuming from the "
                f"checkpoint loses the difference", RuntimeWarning,
                stacklevel=2)
        L = self.cfg.lanes_per_shard
        scratch = bank_lib.stack_sensor_banks(self._one, L)
        if target.device is not None:
            scratch = jax.device_put(scratch, target.device)
        scratch = bank_lib.place_sensor_bank(scratch, lane, state)
        M, m = self.tracker.max_meas, self.model.m
        for tier_i, z_row, v_row in t.wal:
            zb = np.zeros((L, M, m), np.float32)
            vb = np.zeros((L, M), bool)
            zb[lane], vb[lane] = z_row, v_row
            res = self._step_for(ServiceTier(tier_i))(
                scratch, jnp.asarray(zb), jnp.asarray(vb))
            scratch = res.bank
        target.banks = bank_lib.place_sensor_bank(
            target.banks, lane, bank_lib.slice_sensor_bank(scratch, lane))
        t.shard, t.lane, t.parked = s, lane, False
        # re-snapshot on the new shard so the next failover doesn't
        # replay this WAL again on top of the old checkpoint
        self._checkpoint(t)
