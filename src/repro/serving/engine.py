"""KATANA tracking engine: the paper's serving workload as a batched
request server.

One jitted frame step (predict -> gate -> associate -> update -> spawn
-> prune) services every client per frame — the paper's "single
inference call" — with a fixed-capacity bank per sensor. The engine is
deliberately synchronous-deterministic: requests are padded into the
static measurement slots (Opt-2 discipline), so serving latency is the
latency of one kernel launch regardless of load.

``ShardedBankEngine`` scales the same step across a mesh: banks are
data-parallel over sensors (each sensor's scene is independent), the
step is one pjit call over the stacked banks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bank import BankState, init_bank, init_imm_bank
from repro.core.filters import FilterModel, IMMModel
from repro.core.tracker import TrackerConfig, frame_step, imm_frame_step
from repro.kernels.katana_bank.ops import (katana_bank_sequence,
                                           katana_imm_sequence)


@dataclass
class TrackSnapshot:
    track_id: int
    state: np.ndarray
    hits: int
    age: int
    # IMM engines only: per-mode probabilities (K,), aligned with
    # model.models; None for single-model engines
    mode_probs: Optional[np.ndarray] = None


@dataclass
class EngineStats:
    frames: int = 0
    total_latency_s: float = 0.0
    measurements: int = 0
    # offline replay is tracked separately so the real-time serving fps
    # metric is never diluted by batch dispatches
    replay_frames: int = 0
    replay_latency_s: float = 0.0

    @property
    def fps(self) -> float:
        return self.frames / self.total_latency_s if self.total_latency_s else 0.0

    @property
    def replay_fps(self) -> float:
        return (self.replay_frames / self.replay_latency_s
                if self.replay_latency_s else 0.0)


class TrackingEngine:
    """Single-sensor engine: submit measurements per frame, get
    confirmed tracks back.

    Accepts a plain FilterModel or an IMMModel — an IMM engine runs the
    multi-model frame step (K hypotheses per slot) and reports the
    moment-matched combined state plus per-mode probabilities in every
    snapshot; the serving surface is otherwise identical."""

    def __init__(self, model, cfg: Optional[TrackerConfig] = None):
        self.model = model
        self.cfg = cfg or TrackerConfig()
        self.is_imm = isinstance(model, IMMModel)
        if self.is_imm:
            self.bank = init_imm_bank(model, self.cfg.capacity,
                                      jnp.dtype(self.cfg.dtype))
            self._step = jax.jit(
                lambda bank, z, valid: imm_frame_step(model, self.cfg, bank,
                                                      z, valid))
        else:
            self.bank = init_bank(model, self.cfg.capacity,
                                  jnp.dtype(self.cfg.dtype))
            self._step = jax.jit(
                lambda bank, z, valid: frame_step(model, self.cfg, bank, z,
                                                  valid))
        self.stats = EngineStats()
        # warm the compile so serving latency excludes tracing
        z0 = jnp.zeros((self.cfg.max_meas, model.m), jnp.float32)
        v0 = jnp.zeros((self.cfg.max_meas,), bool)
        self._step(self.bank, z0, v0).bank.x.block_until_ready()

    def submit(self, measurements: np.ndarray) -> List[TrackSnapshot]:
        """measurements: (k, m) this frame (k <= max_meas)."""
        mm = np.zeros((self.cfg.max_meas, self.model.m), np.float32)
        vv = np.zeros((self.cfg.max_meas,), bool)
        k = min(len(measurements), self.cfg.max_meas)
        if k:
            mm[:k] = measurements[:k]
            vv[:k] = True
        t0 = time.perf_counter()
        res = self._step(self.bank, jnp.asarray(mm), jnp.asarray(vv))
        res.bank.x.block_until_ready()
        self.stats.total_latency_s += time.perf_counter() - t0
        self.stats.frames += 1
        self.stats.measurements += int(k)
        self.bank = res.bank
        conf = np.asarray(res.confirmed)
        ids = np.asarray(self.bank.track_id)
        # IMM: report the combined (moment-matched) state, not the
        # model-conditioned bank.x
        xs = np.asarray(res.x_est if res.x_est is not None else self.bank.x)
        mus = (np.asarray(res.mode_probs) if res.mode_probs is not None
               else None)
        hits = np.asarray(self.bank.hits)
        age = np.asarray(self.bank.age)
        return [TrackSnapshot(int(ids[i]), xs[i].copy(), int(hits[i]),
                              int(age[i]),
                              mus[i].copy() if mus is not None else None)
                for i in np.nonzero(conf)[0]]

    def replay(self, zs: np.ndarray, x0: Optional[np.ndarray] = None,
               P0: Optional[np.ndarray] = None) -> np.ndarray:
        """Batch-filter a pre-associated (T, N, m) measurement stream in
        ONE fused kernel dispatch (the ``fused_scan`` stage).

        This is the offline/replay companion to ``submit``: when the
        measurement->track association is already known (log replay,
        re-scoring, smoothing passes), the per-frame gate/assign
        machinery is pure overhead — the whole sequence runs inside
        ``katana_bank_sequence`` with x/P kernel-resident across
        frames. Returns the (T, N, n) filtered states. Does not touch
        the live bank, and is accounted under the replay_* stats so the
        real-time serving fps stays meaningful. IMM engines replay
        through ``katana_imm_sequence`` — the fused IMM scan (mixing and
        mode posterior inside the kernel's time loop, one dispatch per
        chunk), combined estimates out.
        """
        zs = np.asarray(zs, np.float32)
        T, N, m = zs.shape
        if x0 is None:
            x0 = np.tile(self.model.x0, (N, 1)).astype(np.float32)
        if P0 is None:
            P0 = np.tile(self.model.P0, (N, 1, 1)).astype(np.float32)
        seq = katana_imm_sequence if self.is_imm else katana_bank_sequence
        t0 = time.perf_counter()
        out = seq(self.model, jnp.asarray(zs),
                  jnp.asarray(x0, jnp.float32),
                  jnp.asarray(P0, jnp.float32))
        out.block_until_ready()
        self.stats.replay_latency_s += time.perf_counter() - t0
        self.stats.replay_frames += T
        return np.asarray(out)


class ShardedBankEngine:
    """S independent sensors, one pjit'd step over stacked banks.

    Banks stack on a leading sensor axis sharded over the mesh data
    axes; association stays per-sensor (vmapped), so the whole fleet's
    frame is one XLA program — the pod-scale version of the paper's
    N=200 batching."""

    def __init__(self, model: FilterModel, n_sensors: int,
                 cfg: Optional[TrackerConfig] = None, mesh=None):
        self.model = model
        self.cfg = cfg or TrackerConfig(capacity=64, max_meas=32)
        self.n = n_sensors
        one = init_bank(model, self.cfg.capacity, jnp.dtype(self.cfg.dtype))
        self.banks = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_sensors,) + x.shape).copy(), one)
        step = jax.vmap(
            lambda bank, z, valid: frame_step(model, self.cfg, bank, z, valid))
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            data_axes = tuple(a for a in mesh.axis_names
                              if a in ("pod", "data"))
            sh = NamedSharding(mesh, P(data_axes))
            self.banks = jax.tree.map(
                lambda x: jax.device_put(x, NamedSharding(
                    mesh, P(*( (data_axes,) + (None,) * (x.ndim - 1))))),
                self.banks)
            self._step = jax.jit(step)
        else:
            self._step = jax.jit(step)

    def frame(self, z: np.ndarray, valid: np.ndarray):
        """z: (S, max_meas, m); valid: (S, max_meas)."""
        res = self._step(self.banks, jnp.asarray(z, jnp.float32),
                         jnp.asarray(valid))
        self.banks = res.bank
        return res
