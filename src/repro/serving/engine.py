"""KATANA tracking engine: the paper's serving workload as a batched
request server.

One jitted frame step (predict -> gate -> associate -> update -> spawn
-> prune) services every client per frame — the paper's "single
inference call" — with a fixed-capacity bank per sensor. Under
``TrackerConfig.fused_frame`` (the default) the measurement cycle of
that step IS one ``katana_frame``/``katana_imm_frame`` Pallas dispatch
(gating and greedy assignment in-kernel, only spawn/prune bookkeeping
in XLA), so the closed-loop FPS the engine reports is the fused-kernel
number; ``fused_frame=False`` serves the einsum oracle path instead.
The engine is deliberately synchronous-deterministic: requests are
padded into the static measurement slots (Opt-2 discipline), so
serving latency is the latency of one kernel launch regardless of
load.

``ShardedBankEngine`` scales the same step across a mesh: banks are
data-parallel over sensors (each sensor's scene is independent), the
sensor axis is shard_mapped over the mesh data axes, and the step —
single-model or the full IMM multi-model cycle — is one XLA program
over the stacked banks. The IMM bank shards as (K, S, C, n): model
axis K replicated-by-construction (it's the lane-stacking axis inside
a shard), sensors S split across the mesh, so every shard runs the
bitwise-identical per-sensor ``imm_frame_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import bank as bank_lib
from repro.core.bank import BankState, init_bank, init_imm_bank
from repro.core.filters import FilterModel, IMMModel, as_imm
from repro.core.tracker import (FrameResult, TrackerConfig, frame_step,
                                imm_frame_step, make_multi_sensor_step)
from repro.kernels.katana_bank.ops import (katana_bank_sequence,
                                           katana_imm_sequence)
from repro.sharding.rules import make_context, sensor_specs


@dataclass
class TrackSnapshot:
    track_id: int
    state: np.ndarray
    hits: int
    age: int
    # IMM engines only: per-mode probabilities (K,), aligned with
    # model.models; None for single-model engines
    mode_probs: Optional[np.ndarray] = None


@dataclass
class EngineStats:
    frames: int = 0
    total_latency_s: float = 0.0
    measurements: int = 0
    # offline replay is tracked separately so the real-time serving fps
    # metric is never diluted by batch dispatches
    replay_frames: int = 0
    replay_latency_s: float = 0.0

    @property
    def fps(self) -> float:
        return self.frames / self.total_latency_s if self.total_latency_s else 0.0

    @property
    def replay_fps(self) -> float:
        return (self.replay_frames / self.replay_latency_s
                if self.replay_latency_s else 0.0)


class TrackingEngine:
    """Single-sensor engine: submit measurements per frame, get
    confirmed tracks back.

    Accepts a plain FilterModel or an IMMModel — an IMM engine runs the
    multi-model frame step (K hypotheses per slot) and reports the
    moment-matched combined state plus per-mode probabilities in every
    snapshot; the serving surface is otherwise identical."""

    def __init__(self, model, cfg: Optional[TrackerConfig] = None):
        self.model = model
        self.cfg = cfg or TrackerConfig()
        # the resolved execution mode (KATANA_MODE / cfg.mode): recorded
        # here so serving telemetry can always say whether the kernels
        # ran compiled or through the interpreter
        self.exec_mode = self.cfg.exec_mode()
        self.is_imm = isinstance(model, IMMModel)
        if self.is_imm:
            self.bank = init_imm_bank(model, self.cfg.capacity,
                                      jnp.dtype(self.cfg.dtype))
            self._step = jax.jit(
                lambda bank, z, valid: imm_frame_step(model, self.cfg, bank,
                                                      z, valid))
        else:
            self.bank = init_bank(model, self.cfg.capacity,
                                  jnp.dtype(self.cfg.dtype))
            self._step = jax.jit(
                lambda bank, z, valid: frame_step(model, self.cfg, bank, z,
                                                  valid))
        self.stats = EngineStats()
        # warm the compile so serving latency excludes tracing
        z0 = jnp.zeros((self.cfg.max_meas, model.m), jnp.float32)
        v0 = jnp.zeros((self.cfg.max_meas,), bool)
        self._step(self.bank, z0, v0).bank.x.block_until_ready()

    def submit(self, measurements: np.ndarray) -> List[TrackSnapshot]:
        """measurements: (k, m) this frame (k <= max_meas)."""
        mm = np.zeros((self.cfg.max_meas, self.model.m), np.float32)
        vv = np.zeros((self.cfg.max_meas,), bool)
        k = min(len(measurements), self.cfg.max_meas)
        if k:
            mm[:k] = measurements[:k]
            vv[:k] = True
        t0 = time.perf_counter()
        res = self._step(self.bank, jnp.asarray(mm), jnp.asarray(vv))
        res.bank.x.block_until_ready()
        self.stats.total_latency_s += time.perf_counter() - t0
        self.stats.frames += 1
        self.stats.measurements += int(k)
        self.bank = res.bank
        conf = np.asarray(res.confirmed)
        ids = np.asarray(self.bank.track_id)
        # IMM: report the combined (moment-matched) state, not the
        # model-conditioned bank.x
        xs = np.asarray(res.x_est if res.x_est is not None else self.bank.x)
        mus = (np.asarray(res.mode_probs) if res.mode_probs is not None
               else None)
        hits = np.asarray(self.bank.hits)
        age = np.asarray(self.bank.age)
        return [TrackSnapshot(int(ids[i]), xs[i].copy(), int(hits[i]),
                              int(age[i]),
                              mus[i].copy() if mus is not None else None)
                for i in np.nonzero(conf)[0]]

    def replay(self, zs: np.ndarray, x0: Optional[np.ndarray] = None,
               P0: Optional[np.ndarray] = None) -> np.ndarray:
        """Batch-filter a pre-associated (T, N, m) measurement stream in
        ONE fused kernel dispatch (the ``fused_scan`` stage).

        This is the offline/replay companion to ``submit``: when the
        measurement->track association is already known (log replay,
        re-scoring, smoothing passes), the per-frame gate/assign
        machinery is pure overhead — the whole sequence runs inside
        ``katana_bank_sequence`` with x/P kernel-resident across
        frames. Returns the (T, N, n) filtered states. Does not touch
        the live bank, and is accounted under the replay_* stats so the
        real-time serving fps stays meaningful. IMM engines replay
        through ``katana_imm_sequence`` — the fused IMM scan (mixing and
        mode posterior inside the kernel's time loop, one dispatch per
        chunk), combined estimates out.
        """
        zs = np.asarray(zs, np.float32)
        T, N, m = zs.shape
        if x0 is None:
            x0 = np.tile(self.model.x0, (N, 1)).astype(np.float32)
        if P0 is None:
            P0 = np.tile(self.model.P0, (N, 1, 1)).astype(np.float32)
        seq = katana_imm_sequence if self.is_imm else katana_bank_sequence
        t0 = time.perf_counter()
        out = seq(self.model, jnp.asarray(zs),
                  jnp.asarray(x0, jnp.float32),
                  jnp.asarray(P0, jnp.float32),
                  interpret=self.exec_mode.interpret)
        out.block_until_ready()
        self.stats.replay_latency_s += time.perf_counter() - t0
        self.stats.replay_frames += T
        return np.asarray(out)


class ShardedBankEngine:
    """S independent sensors, one sharded step over stacked banks.

    Accepts a plain FilterModel or an IMMModel, exactly like
    ``TrackingEngine``: an IMM fleet runs ``imm_frame_step`` per sensor
    (K hypotheses per slot, spawn/prune lifecycle and track ids shared
    across hypotheses) and every ``frame`` returns the stacked
    per-sensor ``FrameResult`` with mode probabilities and the
    moment-matched combined estimates.

    Banks stack on a sensor axis (position 1 — after the model axis K —
    for the IMM x/P leaves, leading elsewhere: the (K, S, C, n)
    placement) that is shard_mapped over the mesh data axes
    (``sharding.rules.sensor_specs`` + ``repro.compat.shard_map``).
    Association stays per-sensor (vmapped), sensors are independent, so
    the step carries zero collectives and every shard computes the
    bitwise-identical unsharded per-sensor frame — the pod-scale
    version of the paper's N=200 batching. Without a mesh the same
    vmapped step runs as one jit call (the S=local case).
    """

    def __init__(self, model, n_sensors: int,
                 cfg: Optional[TrackerConfig] = None, mesh=None):
        self.model = model
        self.cfg = cfg or TrackerConfig(capacity=64, max_meas=32)
        self.exec_mode = self.cfg.exec_mode()
        self.n = n_sensors
        self.is_imm = isinstance(model, IMMModel)
        self.mesh = mesh
        one, axes, step = make_multi_sensor_step(model, self.cfg)
        self._axes = axes
        self.banks = bank_lib.stack_sensor_banks(one, n_sensors)
        self.stats = EngineStats()
        self._ctx = make_context(mesh)
        self._bank_specs = sensor_specs(axes, self.banks, self._ctx)
        self._replay_fns: Dict[bool, callable] = {}
        if mesh is None:
            self._step = jax.jit(step)
        else:
            if n_sensors % self._ctx.data_size:
                raise ValueError(
                    f"n_sensors={n_sensors} must divide over the mesh "
                    f"data axes (size {self._ctx.data_size})")
            res_specs = FrameResult(
                bank=self._bank_specs,
                assoc=self._ctx.batch_spec(2),
                unassigned=self._ctx.batch_spec(2),
                confirmed=self._ctx.batch_spec(2),
                mode_probs=self._ctx.batch_spec(3),
                x_est=self._ctx.batch_spec(3))
            self._step = jax.jit(compat.shard_map(
                step, mesh=mesh,
                in_specs=(self._bank_specs, self._ctx.batch_spec(3),
                          self._ctx.batch_spec(2)),
                out_specs=res_specs))
            self.banks = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                self.banks, self._bank_specs)
        # warm the compile so serving latency excludes tracing
        z0 = jnp.zeros((n_sensors, self.cfg.max_meas, model.m), jnp.float32)
        v0 = jnp.zeros((n_sensors, self.cfg.max_meas), bool)
        self._step(self.banks, z0, v0).bank.x.block_until_ready()

    def frame(self, z: np.ndarray, valid: np.ndarray) -> FrameResult:
        """z: (S, max_meas, m); valid: (S, max_meas). Returns the
        stacked per-sensor FrameResult (sensor-leading leaves; for IMM
        engines ``mode_probs (S, C, K)`` and ``x_est (S, C, n)``)."""
        t0 = time.perf_counter()
        res = self._step(self.banks, jnp.asarray(z, jnp.float32),
                         jnp.asarray(valid))
        res.bank.x.block_until_ready()
        self.stats.total_latency_s += time.perf_counter() - t0
        self.stats.frames += 1
        self.stats.measurements += int(np.asarray(valid).sum())
        self.banks = res.bank
        return res

    def snapshots(self, res: FrameResult) -> List[List[TrackSnapshot]]:
        """Per-sensor confirmed-track snapshots from a ``frame`` result
        — the fleet version of ``TrackingEngine.submit``'s return (IMM
        engines report the combined state + mode probabilities)."""
        conf = np.asarray(res.confirmed)
        ids = np.asarray(self.banks.track_id)
        hits = np.asarray(self.banks.hits)
        age = np.asarray(self.banks.age)
        if self.is_imm:
            xs = np.asarray(res.x_est)
            mus = np.asarray(res.mode_probs)
        else:
            xs, mus = np.asarray(self.banks.x), None
        return [[TrackSnapshot(int(ids[s, i]), xs[s, i].copy(),
                               int(hits[s, i]), int(age[s, i]),
                               mus[s, i].copy() if mus is not None else None)
                 for i in np.nonzero(conf[s])[0]]
                for s in range(self.n)]

    def _build_replay(self, has_valid: bool):
        """Jitted (and, under a mesh, shard_mapped) fused-replay fn:
        each shard flattens its local sensors onto the kernel's track
        axis and runs ``katana_imm_sequence`` ONCE — one dispatch per
        track batch per shard, coasting mask included. Single-model
        engines route through the degenerate K=1 IMM, which reduces
        bitwise to the single-model fused scan."""
        imm = self.model if self.is_imm else as_imm(self.model)
        C, K, n, m = self.cfg.capacity, imm.K, imm.n, imm.m
        is_imm = self.is_imm
        interp = self.exec_mode.interpret

        def body(banks, zs, *rest):
            T, S_loc = zs.shape[0], zs.shape[1]
            if is_imm:
                x0 = banks.x.reshape(K, S_loc * C, n)
                P0 = banks.P.reshape(K, S_loc * C, n, n)
                mu0 = banks.mu.reshape(S_loc * C, K)
            else:
                x0 = banks.x.reshape(S_loc * C, n)
                P0 = banks.P.reshape(S_loc * C, n, n)
                mu0 = None
            v = rest[0].reshape(T, S_loc * C) if rest else None
            out = katana_imm_sequence(imm, zs.reshape(T, S_loc * C, m),
                                      x0, P0, mu0=mu0, valid=v,
                                      interpret=interp)
            return out.reshape(T, S_loc, C, n)

        if self.mesh is None:
            return jax.jit(body)
        zspec = P(None, self._ctx.data_axes, None, None)
        in_specs = (self._bank_specs, zspec) + (
            (P(None, self._ctx.data_axes, None),) if has_valid else ())
        return jax.jit(compat.shard_map(body, mesh=self.mesh,
                                        in_specs=in_specs, out_specs=zspec))

    def replay(self, zs: np.ndarray,
               valid: Optional[np.ndarray] = None) -> np.ndarray:
        """Batch-refilter per-sensor pre-associated streams through the
        fused scan, seeded from the LIVE banks.

        zs: (T, S, C, m) slot-aligned measurement streams (C = the
        bank capacity — row c of sensor s feeds slot c, the
        ``replay_imm_bank`` contract per sensor); valid: optional
        (T, S, C) coasting mask (False = no measurement that frame:
        time update only, mu <- the Markov-predicted cbar). IMM engines
        resume the mode-conditioned (x, P, mu); the whole fleet is one
        ``katana_imm_sequence`` dispatch per track batch per shard.
        Returns the (T, S, C, n) moment-matched combined estimates.
        Does not modify the live banks; accounted under the replay_*
        stats like ``TrackingEngine.replay``.
        """
        zs = jnp.asarray(np.asarray(zs, np.float32))
        T, S, C, _ = zs.shape
        assert S == self.n and C == self.cfg.capacity, (zs.shape, self.n,
                                                        self.cfg.capacity)
        has_valid = valid is not None
        if has_valid not in self._replay_fns:
            self._replay_fns[has_valid] = self._build_replay(has_valid)
        args = (self.banks, zs) + (
            (jnp.asarray(np.asarray(valid, bool)),) if has_valid else ())
        t0 = time.perf_counter()
        out = self._replay_fns[has_valid](*args)
        out.block_until_ready()
        self.stats.replay_latency_s += time.perf_counter() - t0
        self.stats.replay_frames += T
        return np.asarray(out)
