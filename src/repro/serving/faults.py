"""Deterministic fault injection for the streaming front end.

Everything the serving layer claims to survive is injected here, on a
fixed schedule keyed by driver cycle, so every chaos run is exactly
reproducible (no wall clock, no RNG shared with the scene):

* **shard crashes** — ``FaultPlan.kill_shards``: at cycle f the shard
  dies silently (``StreamFrontEnd.kill_shard``) and recovery must come
  from the heartbeat timeout + checkpoint/WAL failover path;
* **sensor dropout** — ``dropouts``: during the window the tenant's
  sensor is dark; its frames arrive *empty* (clock ticks with zero
  detections), so its tracks coast and eventually prune — exactly the
  paper's coast-only valid-mask path;
* **corrupt payloads** — ``corruptions``: NaN/inf values overwrite the
  frame; the tracker's ``nan_guard`` must coast those measurements
  instead of poisoning the bank;
* **duplicate / late frames** — ``duplicates``: the previous frame is
  re-submitted with its old sequence number and must be dropped at
  admission;
* **clock skew** — ``skews_s``: the tenant computes its deadlines from
  a skewed clock (``SkewedClock``), so frames can arrive pre-expired;
  the front end must shed them and keep serving everyone else.

``ChaosDriver`` drives a ``StreamFrontEnd`` through the plan and
collects a ``ChaosReport``: every admission decision, every applied
update per tenant, every uncaught exception (the chaos suite asserts
this list is EMPTY), and when each killed shard's tenants recovered.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.stream import (Admission, StreamFrontEnd,
                                  TenantUpdate)


class SkewedClock:
    """A clock whose reading is offset from the reference clock — the
    classic mis-synced edge device. Deadlines computed against it are
    wrong by ``skew_s`` in the coordinator's frame."""

    def __init__(self, base: Callable[[], float], skew_s: float):
        self.base = base
        self.skew_s = skew_s

    def __call__(self) -> float:
        return self.base() + self.skew_s


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule, all keyed by driver cycle."""

    # cycle -> shard (idx or name) to kill at the START of that cycle
    kill_shards: Dict[int, object] = field(default_factory=dict)
    # tenant -> (start, end) cycles of sensor dropout (dark sensor)
    dropouts: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    # (tenant, cycle) -> "nan" | "inf": poison that frame's payload
    corruptions: Dict[Tuple[str, int], str] = field(default_factory=dict)
    # (tenant, cycle): re-submit the previous frame with its old seq
    duplicates: Tuple[Tuple[str, int], ...] = ()
    # tenant -> clock skew (s) used for its deadline computation
    skews_s: Dict[str, float] = field(default_factory=dict)


class FaultInjector:
    """Applies a ``FaultPlan`` to one tenant's submissions."""

    def __init__(self, plan: FaultPlan,
                 clock: Callable[[], float]):
        self.plan = plan
        self.clock = clock
        self._clocks = {t: SkewedClock(clock, s)
                        for t, s in plan.skews_s.items()}

    def tenant_clock(self, tenant: str) -> Callable[[], float]:
        return self._clocks.get(tenant, self.clock)

    def payload(self, tenant: str, cycle: int,
                z: np.ndarray) -> np.ndarray:
        """Dropout blanks the frame; corruption poisons it."""
        window = self.plan.dropouts.get(tenant)
        if window is not None and window[0] <= cycle < window[1]:
            return np.zeros((0, z.shape[-1] if z.ndim else 1),
                            np.float32)
        kind = self.plan.corruptions.get((tenant, cycle))
        if kind is not None and len(z):
            z = np.array(z, np.float32, copy=True)
            z[0, 0] = math.nan if kind == "nan" else math.inf
        return z

    def duplicate_of(self, tenant: str, cycle: int) -> bool:
        return (tenant, cycle) in self.plan.duplicates

    def deadline(self, tenant: str, budget_s: Optional[float]
                 ) -> Optional[float]:
        """Absolute deadline as the TENANT computes it — through its
        (possibly skewed) clock."""
        if budget_s is None:
            return None
        return self.tenant_clock(tenant)() + budget_s


@dataclass
class ChaosReport:
    decisions: Dict[str, List[Tuple[int, Admission]]] = field(
        default_factory=dict)
    updates: Dict[str, List[TenantUpdate]] = field(default_factory=dict)
    exceptions: List[BaseException] = field(default_factory=list)
    killed_at: Dict[str, int] = field(default_factory=dict)
    # tenant -> first cycle an update landed after its shard was killed
    recovered_at: Dict[str, int] = field(default_factory=dict)

    def frames_applied(self, tenant: str) -> int:
        return len(self.updates.get(tenant, []))

    def served_fraction(self, tenant: str) -> float:
        ups = self.updates.get(tenant, [])
        if not ups:
            return 0.0
        return sum(u.kind == "served" for u in ups) / len(ups)


class ChaosDriver:
    """Drives a ``StreamFrontEnd`` through a deterministic scenario.

    ``scenes`` maps tenant -> ``scene(cycle) -> (k, m) measurements``.
    Each cycle: scheduled shard kills fire, every tenant submits its
    (fault-injected) frame, the front end pumps once, and the clock
    advances ``dt_s``. Nothing here may raise — any exception is
    captured into the report, because "no uncaught exceptions under
    chaos" is an acceptance criterion, not an aspiration."""

    def __init__(self, front: StreamFrontEnd, plan: FaultPlan,
                 scenes: Dict[str, Callable[[int], np.ndarray]],
                 clock_advance: Callable[[float], None],
                 dt_s: float = 0.1,
                 deadline_budget_s: Optional[float] = None,
                 offered_rate: int = 1):
        self.front = front
        self.plan = plan
        self.scenes = scenes
        self.advance = clock_advance
        self.dt_s = dt_s
        self.budget_s = deadline_budget_s
        # frames submitted per tenant per cycle; the front end serves
        # at most one per pump, so rate > 1 is sustained overload
        self.offered_rate = offered_rate
        self.inject = FaultInjector(plan, front.clock)
        self._subs: Dict[str, int] = {}

    def run(self, cycles: int) -> ChaosReport:
        rep = ChaosReport()
        prev: Dict[str, Tuple[int, np.ndarray]] = {}
        watch: Dict[str, int] = {}  # tenant -> cycle its shard died
        for t in self.scenes:
            rep.decisions[t] = []
            rep.updates[t] = []
        for cycle in range(cycles):
            try:
                self._cycle(cycle, rep, prev, watch)
            except Exception as e:  # noqa: BLE001 — report, never raise
                rep.exceptions.append(e)
            self.advance(self.dt_s)
        return rep

    def _cycle(self, cycle: int, rep: ChaosReport,
               prev: Dict[str, Tuple[int, np.ndarray]],
               watch: Dict[str, int]) -> None:
        shard = self.plan.kill_shards.get(cycle)
        if shard is not None:
            sh = self.front._shard(shard)
            rep.killed_at[sh.name] = cycle
            for t in self.front.alloc.tenants_on(sh.idx):
                watch.setdefault(t, cycle)
            self.front.kill_shard(shard)
        for tenant, scene in self.scenes.items():
            if self.inject.duplicate_of(tenant, cycle) and tenant in prev:
                old_seq, old_z = prev[tenant]
                d = self.front.submit(tenant, old_z, seq=old_seq)
                rep.decisions[tenant].append((cycle, d))
            for _ in range(self.offered_rate):
                i = self._subs.get(tenant, 0)
                self._subs[tenant] = i + 1
                z = self.inject.payload(tenant, cycle,
                                        np.asarray(scene(i), np.float32))
                deadline = self.inject.deadline(tenant, self.budget_s)
                seq = self.front.tenants[tenant].next_seq
                d = self.front.submit(tenant, z, deadline=deadline)
                rep.decisions[tenant].append((cycle, d))
                if d in (Admission.ACCEPTED, Admission.REPLACED_OLDEST):
                    prev[tenant] = (seq, z)
        for tenant, up in self.front.pump().items():
            rep.updates[tenant].append(up)
            if tenant in watch and tenant not in rep.recovered_at:
                if cycle > watch[tenant]:
                    rep.recovered_at[tenant] = cycle
