"""MOT association + track lifecycle on top of the filter bank.

Everything is a single jittable frame-step with static shapes:

  1. predict all slots (batched-lanes rewrite) — this ALSO yields the
     frame's innovation quantities S, S^{-1} and P·Hᵀ, computed exactly
     once,
  2. Mahalanobis gating against the precomputed S^{-1},
  3. greedy globally-ordered assignment (iterated masked argmin — a
     fixed ``max_assign`` rounds of lax.fori_loop),
  4. measurement update of associated slots, reusing the same S^{-1}
     and P·Hᵀ (no second cofactor inversion),
  5. spawn tentative tracks for unassigned measurements,
  6. prune coasted tracks.

The association cost is the squared Mahalanobis distance
``d = y^T S^{-1} y`` using the SAME cofactor inverse the update's
Kalman gain uses — one ``small_inv`` per frame, total; the chi-square
gate defaults to the 99% quantile for the measurement dimension.

``imm_frame_step`` is the multi-model twin: K motion hypotheses per
slot (see ``repro.core.bank.IMMBankState``), IMM mixing inside the
predict, mode-probability-weighted gating, and K reused inverses per
frame (one per model — still nothing inverted twice).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bank as bank_lib
from repro.core.bank import BankState, IMMBankState
from repro.core.filters import FilterModel, IMMModel
from repro.core.rewrites import imm_combine

# 99% chi-square quantiles by dof (m <= 6 covers the paper's workloads)
CHI2_99 = {1: 6.63, 2: 9.21, 3: 11.34, 4: 13.28, 5: 15.09, 6: 16.81}


@dataclass(frozen=True)
class TrackerConfig:
    capacity: int = 256
    max_meas: int = 64
    gate: float = 0.0         # 0 => chi2_99[m]
    max_misses: int = 5
    min_hits: int = 3         # confirmations before a track is "real"
    dtype: str = "float32"
    # Route the frame's measurement cycle (predict + gate + greedy
    # assignment + update) through the fused ``katana_frame`` /
    # ``katana_imm_frame`` Pallas dispatch. The einsum path remains the
    # equivalence oracle (and the automatic fallback for models the
    # kernel can't serve: non-selector H, nonlinear IMM members).
    fused_frame: bool = True
    # Execution mode for the kernel dispatches: None defers to the
    # KATANA_MODE env var ("auto"/"interpret"/"compiled"); an explicit
    # value here pins this tracker. A "compiled" request on a backend
    # that can't lower Pallas falls back to the interpreter loudly
    # (repro.execmode.ExecModeFallbackWarning) — never silently.
    mode: Optional[str] = None
    # Degradation knobs (the streaming front end's service ladder,
    # repro.serving.stream): gate_scale multiplies the chi-square gate —
    # a widened gate keeps tracks associated under degraded measurement
    # quality at the cost of more clutter acceptance. 1.0 = nominal.
    gate_scale: float = 1.0
    # Guard against non-finite measurements: a z row containing NaN/inf
    # is treated as "no detection" (its valid bit is cleared, the slot
    # coasts) instead of poisoning the bank state through the update
    # einsums. Serving front ends rely on this to survive corrupt
    # sensor payloads without a bank reset.
    nan_guard: bool = True

    def exec_mode(self):
        """The resolved ``repro.execmode.ExecMode`` for this tracker."""
        from repro.execmode import resolve_mode

        return resolve_mode(self.mode)


class FrameResult(NamedTuple):
    bank: BankState           # BankState or IMMBankState
    assoc: jnp.ndarray        # (C,) measurement index per slot or -1
    unassigned: jnp.ndarray   # (M,) bool — measurements that spawned
    confirmed: jnp.ndarray    # (C,) bool — active & hits >= min_hits
    # IMM extensions (None for the single-model frame step):
    mode_probs: Optional[jnp.ndarray] = None  # (C, K) per-track mode probs
    x_est: Optional[jnp.ndarray] = None       # (C, n) combined state means


def mahalanobis_cost(z_pred: jnp.ndarray, Sinv: jnp.ndarray,
                     z: jnp.ndarray) -> jnp.ndarray:
    """(C, m), (C, m, m) precomputed S^{-1}, (M, m) -> (C, M) squared
    Mahalanobis. Takes the inverse ``predict_bank`` already produced —
    gating never re-inverts the innovation covariance."""
    y = z[None, :, :] - z_pred[:, None, :]        # (C, M, m)
    return jnp.einsum("cMm,cmn,cMn->cM", y, Sinv, y)


def greedy_assign(cost: jnp.ndarray, valid: jnp.ndarray, gate: float,
                  rounds: int) -> jnp.ndarray:
    """Globally-ordered greedy assignment.

    cost: (C, M); valid: (C, M) bool (active slot x real measurement,
    within gate). Returns assoc (C,) int32: measurement index or -1.
    Each round picks the global minimum of the masked cost, commits the
    (slot, measurement) pair, and masks its row+column. ``rounds`` is a
    static bound (min(C, M) at most).
    """
    C, M = cost.shape
    BIG = jnp.asarray(jnp.finfo(cost.dtype).max, cost.dtype)
    masked = jnp.where(valid & (cost <= gate), cost, BIG)

    def body(_, carry):
        masked, assoc = carry
        flat = masked.reshape(-1)
        idx = jnp.argmin(flat)
        c, mm = idx // M, idx % M
        ok = flat[idx] < BIG
        assoc = jnp.where(ok, assoc.at[c].set(mm.astype(jnp.int32)), assoc)
        row_mask = jnp.arange(C) == c
        col_mask = jnp.arange(M) == mm
        kill = row_mask[:, None] | col_mask[None, :]
        masked = jnp.where(ok & kill, BIG, masked)
        return masked, assoc

    assoc0 = jnp.full((C,), -1, jnp.int32)
    _, assoc = jax.lax.fori_loop(0, rounds, body, (masked, assoc0))
    return assoc


def _use_fused_frame(model, cfg: TrackerConfig) -> bool:
    from repro.kernels.katana_bank.ops import frame_kernel_supported

    return cfg.fused_frame and frame_kernel_supported(model)


def _frame_inputs(model, cfg: TrackerConfig, z: jnp.ndarray,
                  z_valid: jnp.ndarray):
    """Shared frame-step preamble: the (scaled) gate, the assignment
    round bound, the dtype-cast measurements and the (possibly
    NaN-guarded) validity mask.

    Applied BEFORE the fused/einsum route split so both paths see
    bit-identical inputs — the equivalence oracle covers the guarded
    path for free. With all-finite measurements the guard is the
    identity (bitwise)."""
    dtype = jnp.dtype(cfg.dtype)
    gate = (cfg.gate or CHI2_99.get(model.m, 16.0)) * cfg.gate_scale
    rounds = min(cfg.capacity, cfg.max_meas)
    zt = z.astype(dtype)
    if cfg.nan_guard:
        finite = jnp.isfinite(zt).all(axis=-1)
        z_valid = z_valid & finite
        # zero (not just mask) the corrupt rows: 0·NaN = NaN would still
        # poison the update einsums the select runs after
        zt = jnp.where(finite[:, None], zt, 0.0)
    return dtype, float(gate), rounds, zt, z_valid


def frame_step(model: FilterModel, cfg: TrackerConfig, bank: BankState,
               z: jnp.ndarray, z_valid: jnp.ndarray) -> FrameResult:
    """One tracking frame. z: (max_meas, m); z_valid: (max_meas,) bool.

    Under ``cfg.fused_frame`` (the default) the measurement cycle —
    predict, innovation, gated Mahalanobis cost, greedy assignment,
    Kalman update — is ONE ``katana_frame`` Pallas dispatch; XLA keeps
    only the spawn/prune lifecycle bookkeeping. The einsum branch below
    is the equivalence oracle (identical assoc/ids, float32-tolerance
    states — tests/test_frame_kernel.py) and the fallback for models
    outside the kernel's contract."""
    dtype, gate, rounds, zt, z_valid = _frame_inputs(model, cfg, z, z_valid)
    if _use_fused_frame(model, cfg):
        from repro.kernels.katana_bank.ops import katana_frame

        x2, P2, assoc = katana_frame(model, bank.x, bank.P, zt, z_valid,
                                     bank.active, gate=float(gate),
                                     rounds=rounds,
                                     interpret=cfg.exec_mode().interpret)
        hits, misses, age = bank_lib.lifecycle_counters(bank, assoc)
        bank_u = bank._replace(x=x2, P=P2, hits=hits, misses=misses,
                               age=age)
    else:
        bank_p, z_pred, _S, Sinv, PHt = bank_lib.predict_bank(model, bank,
                                                              dtype)
        cost = mahalanobis_cost(z_pred, Sinv, zt)
        valid = bank_p.active[:, None] & z_valid[None, :]
        assoc = greedy_assign(cost, valid, jnp.asarray(gate, dtype), rounds)
        bank_u = bank_lib.update_bank(model, bank_p, zt, assoc, PHt, Sinv,
                                      dtype)
    taken = jnp.zeros((cfg.max_meas,), bool).at[
        jnp.clip(assoc, 0, cfg.max_meas - 1)
    ].max(assoc >= 0)
    unassigned = z_valid & ~taken
    bank_s = bank_lib.spawn_tracks(model, bank_u, zt, unassigned, dtype)
    bank_f = bank_lib.prune_bank(bank_s, cfg.max_misses)
    confirmed = bank_f.active & (bank_f.hits >= cfg.min_hits)
    return FrameResult(bank_f, assoc, unassigned, confirmed)


def imm_frame_step(imm: IMMModel, cfg: TrackerConfig, bank: IMMBankState,
                   z: jnp.ndarray, z_valid: jnp.ndarray) -> FrameResult:
    """One IMM tracking frame (the multi-model ``frame_step``).

    Same single-pass discipline: ``predict_imm_bank`` performs the IMM
    mixing and produces every innovation quantity once per (model,
    frame); gating, the K measurement updates AND the mode likelihoods
    all reuse them (K ``small_inv`` calls per frame for K models —
    nothing is inverted twice). Gating uses the mode-probability-
    weighted Mahalanobis distance sum_k cbar_k · d_k, so a maneuver
    hypothesis with high predicted probability widens the gate in the
    right direction. ``FrameResult.mode_probs`` carries the per-track
    mode posterior; ``FrameResult.x_est`` the moment-matched combined
    state (use it instead of ``bank.x``, which is model-conditioned).

    Under ``cfg.fused_frame`` (the default) the whole cycle — mixing,
    K predicts, the weighted gate, assignment, K updates, mode
    posterior and the combined estimate — is ONE ``katana_imm_frame``
    dispatch; XLA keeps spawn/prune and patches the combined estimate
    of freshly-spawned slots (their combined state IS the seed state).
    """
    dtype, gate, rounds, zt, z_valid = _frame_inputs(imm, cfg, z, z_valid)
    fused = _use_fused_frame(imm, cfg)
    if fused:
        from repro.kernels.katana_bank.ops import katana_imm_frame

        x2, P2, mu2, x_c, assoc = katana_imm_frame(
            imm, bank.x, bank.P, bank.mu, zt, z_valid, bank.active,
            gate=float(gate), rounds=rounds,
            interpret=cfg.exec_mode().interpret)
        hits, misses, age = bank_lib.lifecycle_counters(bank, assoc)
        bank_u = bank._replace(x=x2, P=P2, mu=mu2, hits=hits,
                               misses=misses, age=age)
    else:
        bank_p, z_pred, S, Sinv, PHt, cbar = bank_lib.predict_imm_bank(
            imm, bank, dtype)
        cost = sum(cbar[:, k, None] * mahalanobis_cost(z_pred[k], Sinv[k],
                                                       zt)
                   for k in range(imm.K))
        valid = bank_p.active[:, None] & z_valid[None, :]
        assoc = greedy_assign(cost, valid, jnp.asarray(gate, dtype), rounds)
        bank_u = bank_lib.update_imm_bank(imm, bank_p, zt, assoc, z_pred,
                                          PHt, Sinv, S, cbar, dtype)
    taken = jnp.zeros((cfg.max_meas,), bool).at[
        jnp.clip(assoc, 0, cfg.max_meas - 1)
    ].max(assoc >= 0)
    unassigned = z_valid & ~taken
    bank_s = bank_lib.spawn_imm_tracks(imm, bank_u, zt, unassigned, dtype)
    bank_f = bank_lib.prune_bank(bank_s, cfg.max_misses)
    confirmed = bank_f.active & (bank_f.hits >= cfg.min_hits)
    if fused:
        # the kernel's moment-matched combination covers every surviving
        # slot; a slot spawned THIS frame seeds all modes identically,
        # so its combined state is exactly the seed (model-0 slab)
        spawned = bank_s.active & ~bank_u.active
        x_est = jnp.where(spawned[:, None], bank_f.x[0], x_c)
    else:
        x_est, _ = imm_combine(bank_f.x, bank_f.P, bank_f.mu)
    return FrameResult(bank_f, assoc, unassigned, confirmed,
                       mode_probs=bank_f.mu, x_est=x_est)


def make_multi_sensor_step(model, cfg: TrackerConfig):
    """Build the S-sensor frame step: ``frame_step`` (FilterModel) or
    ``imm_frame_step`` (IMMModel) vmapped over a sensor axis.

    Returns ``(bank, axes, step)`` where ``bank`` is one empty
    single-sensor bank, ``axes`` the sensor-axis pytree
    (``bank.bank_sensor_axes`` — sensor axis 1 for the model-
    conditioned IMM leaves, 0 elsewhere) and
    ``step(banks, z, valid)`` maps ``z (S, max_meas, m)`` /
    ``valid (S, max_meas)`` over S independent sensors in one XLA
    program. Association, spawn/prune lifecycle and (for IMM) the
    shared-across-hypotheses track ids all stay strictly per-sensor —
    vmap carries no cross-sensor coupling, which is what makes the
    step shard_map-able with zero collectives
    (``repro.serving.engine.ShardedBankEngine``)."""
    is_imm = isinstance(model, IMMModel)
    one = (bank_lib.init_imm_bank if is_imm else bank_lib.init_bank)(
        model, cfg.capacity, jnp.dtype(cfg.dtype))
    axes = bank_lib.bank_sensor_axes(one)
    base = imm_frame_step if is_imm else frame_step
    out_axes = FrameResult(bank=axes, assoc=0, unassigned=0, confirmed=0,
                           mode_probs=0, x_est=0)
    step = jax.vmap(
        lambda bank, z, valid: base(model, cfg, bank, z, valid),
        in_axes=(axes, 0, 0), out_axes=out_axes)
    return one, axes, step


def make_jitted_tracker(model: FilterModel, cfg: TrackerConfig):
    """Returns (init_bank, step) with step jitted over (bank, z, valid)."""

    def init():
        return bank_lib.init_bank(model, cfg.capacity, jnp.dtype(cfg.dtype))

    @jax.jit
    def step(bank: BankState, z: jnp.ndarray, z_valid: jnp.ndarray):
        return frame_step(model, cfg, bank, z, z_valid)

    return init, step


def make_jitted_imm_tracker(imm: IMMModel, cfg: TrackerConfig):
    """IMM twin of ``make_jitted_tracker``: (init, step) over an
    IMMBankState — still one jittable call per frame."""

    def init():
        return bank_lib.init_imm_bank(imm, cfg.capacity,
                                      jnp.dtype(cfg.dtype))

    @jax.jit
    def step(bank: IMMBankState, z: jnp.ndarray, z_valid: jnp.ndarray):
        return imm_frame_step(imm, cfg, bank, z, z_valid)

    return init, step
