"""KATANA core: filters, NPU->TPU graph rewrites, filter bank, tracker."""
from repro.core.filters import FilterModel, get_filter, make_cv_lkf, make_ctra_ekf  # noqa: F401
from repro.core.rewrites import STAGES, build_stage, run_sequence, small_inv  # noqa: F401
from repro.core.bank import BankState, init_bank  # noqa: F401
from repro.core.tracker import TrackerConfig, frame_step, make_jitted_tracker  # noqa: F401
