"""KATANA core: filters, NPU->TPU graph rewrites, filter bank, tracker,
and the IMM multi-model estimator."""
from repro.core.filters import (FilterModel, IMMModel, as_imm, get_filter,  # noqa: F401
                                make_ca9_lkf, make_ct9_lkf, make_ctra_ekf,
                                make_cv9_lkf, make_cv_lkf, make_imm)
from repro.core.rewrites import (STAGES, build_stage, imm_combine, imm_mix,  # noqa: F401
                                 imm_mode_posterior, run_sequence, small_det,
                                 small_inv)
from repro.core.bank import (BankState, IMMBankState, init_bank,  # noqa: F401
                             init_imm_bank)
from repro.core.tracker import (TrackerConfig, frame_step, imm_frame_step,  # noqa: F401
                                make_jitted_imm_tracker, make_jitted_tracker)
