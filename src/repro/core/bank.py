"""Fixed-capacity filter bank: KATANA's "one inference call, N filters".

The bank is the deployable MOT substrate: a static-shape array of
``capacity`` filter slots (state, covariance, lifecycle counters) that
runs the batched-lanes rewrite every frame. Static shapes everywhere —
slots are (de)activated by masks, never by reshaping — which is exactly
the paper's Opt-2 discipline applied at the *system* level, and what
makes the whole tracker a single jittable step.

Pod-scale MOT shards the bank over the mesh data axis (see
``repro.serving.engine`` / ``repro.launch.serve``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import FilterModel
from repro.core.rewrites import build_batched_lanes, small_inv, stage_constants


class BankState(NamedTuple):
    x: jnp.ndarray        # (C, n) state means
    P: jnp.ndarray        # (C, n, n) covariances
    active: jnp.ndarray   # (C,) bool
    hits: jnp.ndarray     # (C,) int32 — consecutive associations
    misses: jnp.ndarray   # (C,) int32 — consecutive misses
    age: jnp.ndarray      # (C,) int32 — frames since spawn
    track_id: jnp.ndarray  # (C,) int32 — stable external id (-1 = free)
    next_id: jnp.ndarray  # () int32 — id counter


def init_bank(model: FilterModel, capacity: int, dtype=jnp.float32) -> BankState:
    n = model.n
    return BankState(
        x=jnp.zeros((capacity, n), dtype),
        P=jnp.broadcast_to(jnp.asarray(model.P0, dtype), (capacity, n, n)).copy(),
        active=jnp.zeros((capacity,), bool),
        hits=jnp.zeros((capacity,), jnp.int32),
        misses=jnp.zeros((capacity,), jnp.int32),
        age=jnp.zeros((capacity,), jnp.int32),
        track_id=jnp.full((capacity,), -1, jnp.int32),
        next_id=jnp.zeros((), jnp.int32),
    )


def predict_bank(model: FilterModel, bank: BankState,
                 dtype=jnp.float32) -> Tuple[BankState, jnp.ndarray,
                                             jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray]:
    """Time-update every slot (inactive slots are harmlessly propagated —
    static shapes beat branching).

    Returns (bank', z_pred (C, m), S (C, m, m), Sinv (C, m, m),
    PHt (C, n, m)). The innovation covariance, its cofactor inverse and
    P·Hᵀ are computed HERE, exactly once per frame; gating
    (``tracker.mahalanobis_cost``) and the measurement update
    (``update_bank``) consume these instead of rebuilding them — the
    KATANA single-pass discipline applied to the MOT hot path.
    """
    C = stage_constants(model, dtype)
    x, P = bank.x, bank.P
    if model.is_linear:
        x_pred = jnp.einsum("ij,kj->ki", C.F, x)
        FP = jnp.einsum("ij,kjl->kil", C.F, P)
        P_pred = jnp.einsum("kil,jl->kij", FP, C.F) + C.Q
    else:
        x_pred = model.predict_mean(x)
        Fk = model.jacobian(x)
        FP = jnp.einsum("kij,kjl->kil", Fk, P)
        P_pred = jnp.einsum("kil,kjl->kij", FP, Fk) + C.Q
    z_pred = jnp.einsum("mi,ki->km", C.H, x_pred)
    PHt = jnp.einsum("kij,mj->kim", P_pred, C.H)
    S = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
    Sinv = small_inv(S, model.m)
    return bank._replace(x=x_pred, P=P_pred), z_pred, S, Sinv, PHt


def update_bank(model: FilterModel, bank: BankState, z: jnp.ndarray,
                assoc: jnp.ndarray, PHt: Optional[jnp.ndarray] = None,
                Sinv: Optional[jnp.ndarray] = None,
                dtype=jnp.float32) -> BankState:
    """Measurement-update associated slots.

    z: (M, m) padded measurements; assoc: (C,) int32 — index into z for
    each slot, or -1 (no measurement → skip update, bump miss counter).
    PHt (C, n, m) and Sinv (C, m, m) are the innovation quantities
    ``predict_bank`` already computed for this frame — pass them through
    (as ``frame_step`` does) so the update never rebuilds S or inverts
    it a second time. The None fallback recomputes for standalone use.
    Runs the full batched update unconditionally and select-masks the
    result (static shapes; the redundant lanes are the price of zero
    control flow, the same trade the paper makes on the DPU).
    """
    C = stage_constants(model, dtype)
    has_z = assoc >= 0
    zk = z[jnp.clip(assoc, 0, z.shape[0] - 1)]  # (Cap, m), garbage where -1
    x_pred, P_pred = bank.x, bank.P
    if PHt is None:
        PHt = jnp.einsum("kij,mj->kim", P_pred, C.H)
    if Sinv is None:
        S = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
        Sinv = small_inv(S, model.m)
    y = zk + jnp.einsum("mi,ki->km", C.H_neg, x_pred)
    K = jnp.einsum("kim,kmn->kin", PHt, Sinv)
    x_new = x_pred + jnp.einsum("kin,kn->ki", K, y)
    HnP = jnp.einsum("mi,kij->kmj", C.H_neg, P_pred)
    P_new = P_pred + jnp.einsum("kim,kmj->kij", K, HnP)
    P_new = 0.5 * (P_new + jnp.swapaxes(P_new, -1, -2))

    upd = has_z & bank.active
    x_out = jnp.where(upd[:, None], x_new, x_pred)
    P_out = jnp.where(upd[:, None, None], P_new, P_pred)
    hits = jnp.where(upd, bank.hits + 1, bank.hits)
    misses = jnp.where(upd, 0, jnp.where(bank.active, bank.misses + 1,
                                         bank.misses))
    age = jnp.where(bank.active, bank.age + 1, bank.age)
    return bank._replace(x=x_out, P=P_out, hits=hits, misses=misses, age=age)


def spawn_tracks(model: FilterModel, bank: BankState, z: jnp.ndarray,
                 unassigned: jnp.ndarray, dtype=jnp.float32) -> BankState:
    """Open new tracks for unassigned measurements in free slots.

    z: (M, m); unassigned: (M,) bool. Deterministic packing: the j-th
    unassigned measurement claims the j-th free slot (computed with
    cumsum ranks — static shapes, no host round-trip).
    """
    Cap = bank.x.shape[0]
    M = z.shape[0]
    free = ~bank.active  # (Cap,)
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1       # rank among free
    meas_rank = jnp.cumsum(unassigned.astype(jnp.int32)) - 1  # rank among new
    # slot s takes measurement j iff free[s] and meas_rank[j]==free_rank[s]
    take = (free[:, None] & unassigned[None, :] &
            (free_rank[:, None] == meas_rank[None, :]))  # (Cap, M)
    takes_any = take.any(axis=1)
    zsel = jnp.einsum("sm,mq->sq", take.astype(z.dtype), z)  # (Cap, m)
    # init state: measurement mapped through H pseudo-placement (use H^T z
    # — exact for position-selector H), rest of state at model defaults.
    Ht = jnp.asarray(model.H.T, dtype)
    x_init = jnp.einsum("nm,sm->sn", Ht, zsel) + jnp.asarray(
        model.x0, dtype) * (1.0 - jnp.einsum("nm,m->n", Ht, jnp.ones((model.m,), dtype)))
    P_init = jnp.broadcast_to(jnp.asarray(model.P0, dtype),
                              (Cap, model.n, model.n))
    new_ids = bank.next_id + free_rank.astype(jnp.int32)
    return bank._replace(
        x=jnp.where(takes_any[:, None], x_init, bank.x),
        P=jnp.where(takes_any[:, None, None], P_init, bank.P),
        active=bank.active | takes_any,
        hits=jnp.where(takes_any, 1, bank.hits),
        misses=jnp.where(takes_any, 0, bank.misses),
        age=jnp.where(takes_any, 0, bank.age),
        track_id=jnp.where(takes_any, new_ids, bank.track_id),
        next_id=bank.next_id + jnp.sum(takes_any.astype(jnp.int32)),
    )


def prune_bank(bank: BankState, max_misses: int = 5) -> BankState:
    """Retire tracks that coasted too long; their slots become free."""
    dead = bank.active & (bank.misses > max_misses)
    return bank._replace(
        active=bank.active & ~dead,
        track_id=jnp.where(dead, -1, bank.track_id),
        hits=jnp.where(dead, 0, bank.hits),
        misses=jnp.where(dead, 0, bank.misses),
    )
