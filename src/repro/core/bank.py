"""Fixed-capacity filter bank: KATANA's "one inference call, N filters".

The bank is the deployable MOT substrate: a static-shape array of
``capacity`` filter slots (state, covariance, lifecycle counters) that
runs the batched-lanes rewrite every frame. Static shapes everywhere —
slots are (de)activated by masks, never by reshaping — which is exactly
the paper's Opt-2 (§IV-C static-fusion) discipline applied at the
*system* level, and what makes the whole tracker a single jittable step.

``IMMBankState`` is the multi-model extension: every slot carries K
model-conditioned (x, P) pairs plus mode probabilities mu, and the
predict step runs the IMM interaction (mixing) before the K per-model
time updates — the §IV-D batching axis reused for the model index.
Lifecycle (active/hits/misses/age/track_id) stays per-SLOT, shared by
all K hypotheses.

Pod-scale MOT shards the bank over the mesh data axis (see
``repro.serving.engine`` / ``repro.launch.serve``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import FilterModel, IMMModel
from repro.core.rewrites import (build_batched_lanes, gaussian_loglik,
                                 imm_mix, imm_mode_posterior, small_det,
                                 small_inv, stage_constants, sym_unpack,
                                 triu_pack)


class BankState(NamedTuple):
    x: jnp.ndarray        # (C, n) state means
    P: jnp.ndarray        # (C, n, n) covariances
    active: jnp.ndarray   # (C,) bool
    hits: jnp.ndarray     # (C,) int32 — consecutive associations
    misses: jnp.ndarray   # (C,) int32 — consecutive misses
    age: jnp.ndarray      # (C,) int32 — frames since spawn
    track_id: jnp.ndarray  # (C,) int32 — stable external id (-1 = free)
    next_id: jnp.ndarray  # () int32 — id counter


def init_bank(model: FilterModel, capacity: int, dtype=jnp.float32) -> BankState:
    n = model.n
    return BankState(
        x=jnp.zeros((capacity, n), dtype),
        P=jnp.broadcast_to(jnp.asarray(model.P0, dtype), (capacity, n, n)).copy(),
        active=jnp.zeros((capacity,), bool),
        hits=jnp.zeros((capacity,), jnp.int32),
        misses=jnp.zeros((capacity,), jnp.int32),
        age=jnp.zeros((capacity,), jnp.int32),
        track_id=jnp.full((capacity,), -1, jnp.int32),
        next_id=jnp.zeros((), jnp.int32),
    )


def _predict_lanes(model: FilterModel, x: jnp.ndarray, P: jnp.ndarray,
                   dtype=jnp.float32):
    """Batched-lanes time update + innovation quantities for (C, n)
    states: returns (x_pred, P_pred, z_pred, S, Sinv, PHt). This is the
    single place S is built and inverted per (model, frame) — shared by
    the plain and the IMM bank.

    The covariance propagation emits only the upper triangle of
    F·P·Fᵀ + Q and aliases the mirrors (``rewrites.triu_pack``) — the
    kernels' symmetrize=True discipline on the einsum path: exact
    symmetry by construction (no square-then-average pass) at
    n(n+1)/2 instead of n² second-contraction dots."""
    n = model.n
    iu, ju, _ = triu_pack(n)
    C = stage_constants(model, dtype)
    Qtri = C.Q[iu, ju]
    if model.is_linear:
        x_pred = jnp.einsum("ij,kj->ki", C.F, x)
        FP = jnp.einsum("ij,kjl->kil", C.F, P)
        tri = jnp.einsum("ktl,tl->kt", FP[:, iu, :], C.F[ju, :]) + Qtri
    else:
        x_pred = model.predict_mean(x)
        Fk = model.jacobian(x)
        FP = jnp.einsum("kij,kjl->kil", Fk, P)
        tri = jnp.einsum("ktl,ktl->kt", FP[:, iu, :], Fk[:, ju, :]) + Qtri
    P_pred = sym_unpack(tri, n)
    z_pred = jnp.einsum("mi,ki->km", C.H, x_pred)
    PHt = jnp.einsum("kij,mj->kim", P_pred, C.H)
    S = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
    Sinv = small_inv(S, model.m)
    return x_pred, P_pred, z_pred, S, Sinv, PHt


def _kalman_update_lanes(model: FilterModel, x_pred, P_pred, zk, PHt, Sinv,
                         dtype=jnp.float32):
    """Subtract-free (H_neg, paper §IV-B) batched measurement update for
    (C, n) lanes, consuming the precomputed P·Hᵀ and S^{-1}. The
    posterior covariance P̂ + K·(H_neg·P̂) is emitted upper-triangle-only
    with aliased mirrors (exact symmetry — replaces the old
    0.5·(P + Pᵀ) averaging pass, see ``_predict_lanes``)."""
    n = model.n
    iu, ju, _ = triu_pack(n)
    C = stage_constants(model, dtype)
    y = zk + jnp.einsum("mi,ki->km", C.H_neg, x_pred)
    K = jnp.einsum("kim,kmn->kin", PHt, Sinv)
    x_new = x_pred + jnp.einsum("kin,kn->ki", K, y)
    HnP = jnp.einsum("mi,kij->kmj", C.H_neg, P_pred)
    tri = (P_pred[:, iu, ju]
           + jnp.einsum("ktm,kmt->kt", K[:, iu, :], HnP[:, :, ju]))
    return x_new, sym_unpack(tri, n)


def predict_bank(model: FilterModel, bank: BankState,
                 dtype=jnp.float32) -> Tuple[BankState, jnp.ndarray,
                                             jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray]:
    """Time-update every slot (inactive slots are harmlessly propagated —
    static shapes beat branching).

    Returns (bank', z_pred (C, m), S (C, m, m), Sinv (C, m, m),
    PHt (C, n, m)). The innovation covariance, its cofactor inverse and
    P·Hᵀ are computed HERE, exactly once per frame; gating
    (``tracker.mahalanobis_cost``) and the measurement update
    (``update_bank``) consume these instead of rebuilding them — the
    KATANA single-pass discipline applied to the MOT hot path.
    """
    x_pred, P_pred, z_pred, S, Sinv, PHt = _predict_lanes(
        model, bank.x, bank.P, dtype)
    return bank._replace(x=x_pred, P=P_pred), z_pred, S, Sinv, PHt


def update_bank(model: FilterModel, bank: BankState, z: jnp.ndarray,
                assoc: jnp.ndarray, PHt: Optional[jnp.ndarray] = None,
                Sinv: Optional[jnp.ndarray] = None,
                dtype=jnp.float32) -> BankState:
    """Measurement-update associated slots.

    z: (M, m) padded measurements; assoc: (C,) int32 — index into z for
    each slot, or -1 (no measurement → skip update, bump miss counter).
    PHt (C, n, m) and Sinv (C, m, m) are the innovation quantities
    ``predict_bank`` already computed for this frame — pass them through
    (as ``frame_step`` does) so the update never rebuilds S or inverts
    it a second time. The None fallback recomputes for standalone use.
    Runs the full batched update unconditionally and select-masks the
    result (static shapes; the redundant lanes are the price of zero
    control flow, the same trade the paper makes on the DPU).
    """
    C = stage_constants(model, dtype)
    has_z = assoc >= 0
    zk = z[jnp.clip(assoc, 0, z.shape[0] - 1)]  # (Cap, m), garbage where -1
    x_pred, P_pred = bank.x, bank.P
    if PHt is None:
        PHt = jnp.einsum("kij,mj->kim", P_pred, C.H)
    if Sinv is None:
        S = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
        Sinv = small_inv(S, model.m)
    x_new, P_new = _kalman_update_lanes(model, x_pred, P_pred, zk, PHt, Sinv,
                                        dtype)

    upd = has_z & bank.active
    x_out = jnp.where(upd[:, None], x_new, x_pred)
    P_out = jnp.where(upd[:, None, None], P_new, P_pred)
    hits, misses, age = lifecycle_counters(bank, assoc)
    return bank._replace(x=x_out, P=P_out, hits=hits, misses=misses, age=age)


def lifecycle_counters(bank, assoc: jnp.ndarray):
    """The per-slot hit/miss/age advance for one frame, from the
    association result: assoc (C,) measurement index or -1. The ONE
    definition of this algebra — ``update_bank``/``update_imm_bank``
    interleave it with the measurement update, and the tracker's fused
    route (where the kernel owns the state update and XLA only advances
    the integer counters) applies it standalone. Returns (hits, misses,
    age)."""
    upd = (assoc >= 0) & bank.active
    hits = jnp.where(upd, bank.hits + 1, bank.hits)
    misses = jnp.where(upd, 0, jnp.where(bank.active, bank.misses + 1,
                                         bank.misses))
    age = jnp.where(bank.active, bank.age + 1, bank.age)
    return hits, misses, age


def _spawn_plan(active: jnp.ndarray, unassigned: jnp.ndarray):
    """Deterministic free-slot packing: the j-th unassigned measurement
    claims the j-th free slot (cumsum ranks — static shapes, no host
    round-trip). Returns (take (Cap, M), takes_any (Cap,),
    free_rank (Cap,))."""
    free = ~active  # (Cap,)
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1       # rank among free
    meas_rank = jnp.cumsum(unassigned.astype(jnp.int32)) - 1  # rank among new
    # slot s takes measurement j iff free[s] and meas_rank[j]==free_rank[s]
    take = (free[:, None] & unassigned[None, :] &
            (free_rank[:, None] == meas_rank[None, :]))  # (Cap, M)
    return take, take.any(axis=1), free_rank


def _spawn_init_state(model: FilterModel, take: jnp.ndarray, z: jnp.ndarray,
                      dtype=jnp.float32):
    """Measurement-seeded initial state per claiming slot: z mapped
    through Hᵀ (exact for position-selector H), the unobserved state
    components at the model defaults."""
    zsel = jnp.einsum("sm,mq->sq", take.astype(z.dtype), z)  # (Cap, m)
    Ht = jnp.asarray(model.H.T, dtype)
    return jnp.einsum("nm,sm->sn", Ht, zsel) + jnp.asarray(
        model.x0, dtype) * (1.0 - jnp.einsum("nm,m->n", Ht,
                                             jnp.ones((model.m,), dtype)))


def spawn_tracks(model: FilterModel, bank: BankState, z: jnp.ndarray,
                 unassigned: jnp.ndarray, dtype=jnp.float32) -> BankState:
    """Open new tracks for unassigned measurements in free slots.

    z: (M, m); unassigned: (M,) bool.
    """
    Cap = bank.x.shape[0]
    take, takes_any, free_rank = _spawn_plan(bank.active, unassigned)
    x_init = _spawn_init_state(model, take, z, dtype)
    P_init = jnp.broadcast_to(jnp.asarray(model.P0, dtype),
                              (Cap, model.n, model.n))
    new_ids = bank.next_id + free_rank.astype(jnp.int32)
    return bank._replace(
        x=jnp.where(takes_any[:, None], x_init, bank.x),
        P=jnp.where(takes_any[:, None, None], P_init, bank.P),
        active=bank.active | takes_any,
        hits=jnp.where(takes_any, 1, bank.hits),
        misses=jnp.where(takes_any, 0, bank.misses),
        age=jnp.where(takes_any, 0, bank.age),
        track_id=jnp.where(takes_any, new_ids, bank.track_id),
        next_id=bank.next_id + jnp.sum(takes_any.astype(jnp.int32)),
    )


def bank_sensor_axes(bank):
    """Per-leaf sensor-axis positions for stacking this bank over S
    independent sensors — the vmap in/out_axes pytree and the axis the
    serving mesh shards.

    Model-conditioned leaves of an ``IMMBankState`` (x, P) keep the
    model axis K outermost, so the sensor axis slots in at position 1
    and the stacked layout is ``(K, S, C, ...)`` — one contiguous
    (sensor, slot) block per model slab, which is what lets the sharded
    replay flatten a shard's sensors straight onto the kernel's track
    axis. Every other leaf (mu, lifecycle, ids) leads with S.
    """
    if isinstance(bank, IMMBankState):
        return IMMBankState(x=1, P=1, mu=0, active=0, hits=0, misses=0,
                            age=0, track_id=0, next_id=0)
    return BankState(x=0, P=0, active=0, hits=0, misses=0, age=0,
                     track_id=0, next_id=0)


def stack_sensor_banks(bank, n_sensors: int):
    """Broadcast one bank into an S-sensor stack along
    ``bank_sensor_axes`` (every sensor starts from the same empty
    bank). Works on BankState and IMMBankState alike."""

    def put(x, a):
        x = jnp.expand_dims(x, a)
        shape = x.shape[:a] + (n_sensors,) + x.shape[a + 1:]
        return jnp.broadcast_to(x, shape).copy()

    return jax.tree.map(put, bank, bank_sensor_axes(bank))


def slice_sensor_bank(banks, s: int):
    """Extract sensor/lane ``s`` of a stacked bank as a single-sensor
    bank (the inverse of one lane of ``stack_sensor_banks``).

    This is the checkpoint/failover surface: a tenant's lane of the
    serving fleet is snapshotted and restored as a plain
    BankState/IMMBankState pytree, so ``checkpoint.ckpt`` can save it
    and a different shard/lane can receive it without knowing the
    fleet layout. Works on BankState and IMMBankState alike."""
    return jax.tree.map(
        lambda x, a: jax.lax.index_in_dim(x, s, axis=a, keepdims=False),
        banks, bank_sensor_axes(banks))


def place_sensor_bank(banks, s: int, one):
    """Write a single-sensor bank into lane ``s`` of a stacked bank
    (the other lanes untouched) — the restore half of
    ``slice_sensor_bank``. Used by the streaming front end's failover
    path to graft a checkpointed tenant bank onto a surviving shard's
    stack. Returns the new stacked bank."""

    def put(full, x, a):
        idx = tuple(slice(None) for _ in range(a)) + (s,)
        return full.at[idx].set(jnp.asarray(x, full.dtype))

    return jax.tree.map(put, banks, one, bank_sensor_axes(banks))


def prune_bank(bank, max_misses: int = 5):
    """Retire tracks that coasted too long; their slots become free.
    Works on BankState and IMMBankState alike (shared lifecycle
    fields)."""
    dead = bank.active & (bank.misses > max_misses)
    return bank._replace(
        active=bank.active & ~dead,
        track_id=jnp.where(dead, -1, bank.track_id),
        hits=jnp.where(dead, 0, bank.hits),
        misses=jnp.where(dead, 0, bank.misses),
    )


# ---------------------------------------------------------------------------
# IMM multi-model bank: K hypotheses per slot, shared lifecycle.
# ---------------------------------------------------------------------------

class IMMBankState(NamedTuple):
    x: jnp.ndarray        # (K, C, n) model-conditioned state means
    P: jnp.ndarray        # (K, C, n, n) model-conditioned covariances
    mu: jnp.ndarray       # (C, K) mode probabilities (rows sum to 1)
    active: jnp.ndarray   # (C,) bool
    hits: jnp.ndarray     # (C,) int32 — consecutive associations
    misses: jnp.ndarray   # (C,) int32 — consecutive misses
    age: jnp.ndarray      # (C,) int32 — frames since spawn
    track_id: jnp.ndarray  # (C,) int32 — stable external id (-1 = free)
    next_id: jnp.ndarray  # () int32 — id counter


def init_imm_bank(imm: IMMModel, capacity: int,
                  dtype=jnp.float32) -> IMMBankState:
    n, K = imm.n, imm.K
    return IMMBankState(
        x=jnp.zeros((K, capacity, n), dtype),
        P=jnp.broadcast_to(jnp.asarray(imm.P0, dtype),
                           (K, capacity, n, n)).copy(),
        mu=jnp.broadcast_to(jnp.asarray(imm.mu0, dtype),
                            (capacity, K)).copy(),
        active=jnp.zeros((capacity,), bool),
        hits=jnp.zeros((capacity,), jnp.int32),
        misses=jnp.zeros((capacity,), jnp.int32),
        age=jnp.zeros((capacity,), jnp.int32),
        track_id=jnp.full((capacity,), -1, jnp.int32),
        next_id=jnp.zeros((), jnp.int32),
    )


def predict_imm_bank(imm: IMMModel, bank: IMMBankState, dtype=jnp.float32):
    """IMM interaction (mixing) + K model-conditioned time updates.

    Returns (bank', z_pred (K, C, m), S (K, C, m, m), Sinv (K, C, m, m),
    PHt (K, C, n, m), cbar (C, K)). Like ``predict_bank``, every
    innovation quantity is produced exactly once per (model, frame):
    gating, the measurement update AND the mode likelihoods all consume
    these — K ``small_inv`` calls per frame, total, for K models.
    ``cbar`` is the Markov-predicted mode probability (the coasting
    posterior when a track gets no measurement)."""
    Pi = jnp.asarray(imm.trans, dtype)
    x_mix, P_mix, cbar = imm_mix(bank.x, bank.P, bank.mu, Pi)
    outs = [_predict_lanes(model, x_mix[k], P_mix[k], dtype)
            for k, model in enumerate(imm.models)]
    x_pred, P_pred, z_pred, S, Sinv, PHt = (
        jnp.stack([o[i] for o in outs]) for i in range(6))
    return (bank._replace(x=x_pred, P=P_pred), z_pred, S, Sinv, PHt, cbar)


def update_imm_bank(imm: IMMModel, bank: IMMBankState, z: jnp.ndarray,
                    assoc: jnp.ndarray,
                    z_pred: Optional[jnp.ndarray] = None,
                    PHt: Optional[jnp.ndarray] = None,
                    Sinv: Optional[jnp.ndarray] = None,
                    S: Optional[jnp.ndarray] = None,
                    cbar: Optional[jnp.ndarray] = None,
                    dtype=jnp.float32) -> IMMBankState:
    """K model-conditioned measurement updates + the mode posterior.

    z: (M, m) padded measurements; assoc: (C,) measurement index or -1.
    z_pred/PHt/Sinv/S are the (K, ...) innovation quantities from
    ``predict_imm_bank`` — pass them through (as ``imm_frame_step``
    does) so nothing is rebuilt or re-inverted here; the mode
    likelihoods reuse the same S^{-1} as the Kalman gains
    (``gaussian_loglik``). The None fallback recomputes any missing
    quantity from the predicted bank for standalone use (``bank`` must
    be the POST-predict state; its ``mu`` is still the pre-mix
    distribution, so cbar is recoverable from the Markov chain) — same
    expressions as ``_predict_lanes``, so the fallback is bit-identical
    to the pass-through. Associated slots get the Bayes posterior
    mu ∝ cbar·N(y; 0, S); coasting slots keep the Markov-predicted cbar
    (which stays normalized — no renormalization drift while a track
    coasts). Lifecycle counters advance once per slot, not per model.
    """
    m = imm.m
    # each missing quantity recomputes independently — a caller short
    # only of cbar pays no innovation einsums at all
    consts = ([stage_constants(model, dtype) for model in imm.models]
              if z_pred is None or PHt is None or S is None else None)
    if z_pred is None:
        z_pred = jnp.stack([jnp.einsum("mi,ki->km", Ck.H, bank.x[k])
                            for k, Ck in enumerate(consts)])
    if PHt is None:
        PHt = jnp.stack([jnp.einsum("kij,mj->kim", bank.P[k], Ck.H)
                         for k, Ck in enumerate(consts)])
    if S is None:
        # S feeds the likelihood normalizer even when Sinv is given
        S = jnp.stack([jnp.einsum("mi,kij,nj->kmn", Ck.H, bank.P[k], Ck.H)
                       + Ck.R
                       for k, Ck in enumerate(consts)])
    if Sinv is None:
        Sinv = small_inv(S, m)
    if cbar is None:
        cbar = bank.mu @ jnp.asarray(imm.trans, dtype)
    has_z = assoc >= 0
    zk = z[jnp.clip(assoc, 0, z.shape[0] - 1)]  # (C, m), garbage where -1
    x_new, P_new, loglik = [], [], []
    for k, model in enumerate(imm.models):
        xk, Pk = _kalman_update_lanes(model, bank.x[k], bank.P[k], zk,
                                      PHt[k], Sinv[k], dtype)
        x_new.append(xk)
        P_new.append(Pk)
        y = zk - z_pred[k]
        loglik.append(gaussian_loglik(y, Sinv[k],
                                      jnp.log(small_det(S[k], m)), m))
    x_new, P_new = jnp.stack(x_new), jnp.stack(P_new)
    mu_post = imm_mode_posterior(cbar, jnp.stack(loglik))

    upd = has_z & bank.active
    x_out = jnp.where(upd[None, :, None], x_new, bank.x)
    P_out = jnp.where(upd[None, :, None, None], P_new, bank.P)
    mu_out = jnp.where(upd[:, None], mu_post, cbar)
    hits, misses, age = lifecycle_counters(bank, assoc)
    return bank._replace(x=x_out, P=P_out, mu=mu_out, hits=hits,
                         misses=misses, age=age)


def replay_imm_bank(imm: IMMModel, bank: IMMBankState, zs, valid=None,
                    **kw):
    """Re-filter a pre-associated (T, C, m) measurement stream seeded
    from the live bank's mode-conditioned state — one fused IMM scan
    dispatch per time chunk (the ``imm_scan`` stage), with x/P and the
    mode probabilities kernel-resident across frames.

    ``valid`` is an optional (T, C) mask: False frames coast a slot
    (time update only, mu <- cbar), mirroring how ``update_imm_bank``
    treats an unassociated slot. Returns the (T, C, n) moment-matched
    combined estimates; pass ``return_final=True`` through ``kw`` to
    also get the final (x, P, mu) for reseeding a bank. The live bank
    is not modified."""
    from repro.kernels.katana_bank.ops import katana_imm_sequence

    return katana_imm_sequence(imm, zs, bank.x, bank.P, mu0=bank.mu,
                               valid=valid, **kw)


def spawn_imm_tracks(imm: IMMModel, bank: IMMBankState, z: jnp.ndarray,
                     unassigned: jnp.ndarray,
                     dtype=jnp.float32) -> IMMBankState:
    """Open new tracks for unassigned measurements: every mode starts
    from the same measurement-seeded state, covariance P0 and the prior
    mode distribution ``imm.mu0``."""
    K = imm.K
    Cap = bank.x.shape[1]
    take, takes_any, free_rank = _spawn_plan(bank.active, unassigned)
    x_init = _spawn_init_state(imm.models[0], take, z, dtype)  # shared H
    P_init = jnp.broadcast_to(jnp.asarray(imm.P0, dtype),
                              (Cap, imm.n, imm.n))
    mu_init = jnp.broadcast_to(jnp.asarray(imm.mu0, dtype), (Cap, K))
    new_ids = bank.next_id + free_rank.astype(jnp.int32)
    return bank._replace(
        x=jnp.where(takes_any[None, :, None], x_init[None], bank.x),
        P=jnp.where(takes_any[None, :, None, None], P_init[None], bank.P),
        mu=jnp.where(takes_any[:, None], mu_init, bank.mu),
        active=bank.active | takes_any,
        hits=jnp.where(takes_any, 1, bank.hits),
        misses=jnp.where(takes_any, 0, bank.misses),
        age=jnp.where(takes_any, 0, bank.age),
        track_id=jnp.where(takes_any, new_ids, bank.track_id),
        next_id=bank.next_id + jnp.sum(takes_any.astype(jnp.int32)),
    )
