"""float64 numpy oracle for every KATANA stage and kernel.

This is the ground-truth Kalman recursion, written in the clearest
possible form with no performance concerns. All rewrite stages
(baseline / opt1 / opt2 / batched-blockdiag / batched-lanes) and the
Pallas ``katana_bank`` kernel must match it to fp32 tolerance.
"""
from __future__ import annotations

import numpy as np

from repro.core.filters import FilterModel


def predict(model: FilterModel, x: np.ndarray, P: np.ndarray):
    x = np.asarray(x, np.float64)
    P = np.asarray(P, np.float64)
    if model.is_linear:
        F = np.asarray(model.F, np.float64)
        x_pred = F @ x
    else:
        x_pred = model.f_np(x)
        F = model.F_jac_np(x)
    P_pred = F @ P @ F.T + np.asarray(model.Q, np.float64)
    return x_pred, P_pred


def update(model: FilterModel, x_pred: np.ndarray, P_pred: np.ndarray,
           z: np.ndarray):
    H = np.asarray(model.H, np.float64)
    R = np.asarray(model.R, np.float64)
    y = np.asarray(z, np.float64) - H @ x_pred
    S = H @ P_pred @ H.T + R
    K = P_pred @ H.T @ np.linalg.inv(S)
    x_new = x_pred + K @ y
    P_new = (np.eye(model.n) - K @ H) @ P_pred
    P_new = 0.5 * (P_new + P_new.T)
    return x_new, P_new


def step(model: FilterModel, x: np.ndarray, P: np.ndarray, z: np.ndarray):
    return update(model, *predict(model, x, P), z)


def run(model: FilterModel, zs: np.ndarray, x0=None, P0=None):
    """Filter a (T, m) measurement sequence; returns (T, n) states."""
    x = np.asarray(model.x0 if x0 is None else x0, np.float64)
    P = np.asarray(model.P0 if P0 is None else P0, np.float64)
    out = np.zeros((len(zs), model.n))
    covs = np.zeros((len(zs), model.n, model.n))
    for t, z in enumerate(zs):
        x, P = step(model, x, P, z)
        out[t] = x
        covs[t] = P
    return out, covs


def run_batched(model: FilterModel, zs: np.ndarray, x0: np.ndarray,
                P0: np.ndarray):
    """zs: (T, N, m); x0: (N, n); P0: (N, n, n) -> (T, N, n)."""
    T, N, _ = zs.shape
    out = np.zeros((T, N, model.n))
    xs = np.array(x0, np.float64)
    Ps = np.array(P0, np.float64)
    for t in range(T):
        for k in range(N):
            xs[k], Ps[k] = step(model, xs[k], Ps[k], zs[t, k])
        out[t] = xs
    return out, xs, Ps


# ---------------------------------------------------------------------------
# IMM (interacting multiple model) oracle — the textbook recursion in
# float64, one track at a time. The imm_bank stage / katana_bank_imm
# kernel must track this, like every other stage tracks run().
# ---------------------------------------------------------------------------

def imm_step(imm, xs: np.ndarray, Ps: np.ndarray, mu: np.ndarray,
             z: np.ndarray, has_z: bool = True):
    """One IMM cycle for one track.

    xs: (K, n) model-conditioned means; Ps: (K, n, n); mu: (K,) mode
    probabilities; z: (m,). Returns (xs', Ps', mu', x_combined).
    Mixing -> per-model KF predict+update -> mode posterior from the
    Gaussian measurement likelihoods -> moment-matched combination.
    With ``has_z=False`` the track coasts: the measurement update is
    skipped (the model-conditioned states stay at the prediction) and
    the mode posterior is the Markov-predicted cbar — the tracker's
    no-measurement semantics (``bank.update_imm_bank``).
    """
    K = len(imm.models)
    n, m = imm.n, imm.m
    Pi = np.asarray(imm.trans, np.float64)
    mu = np.asarray(mu, np.float64)
    # -- interaction / mixing --
    cbar = Pi.T @ mu                              # (K,) predicted mode probs
    w = Pi * mu[:, None] / cbar[None, :]          # w[i, j] = P(i | j)
    x_mix = np.einsum("ij,id->jd", w, xs)
    P_mix = np.zeros((K, n, n))
    for j in range(K):
        for i in range(K):
            dx = xs[i] - x_mix[j]
            P_mix[j] += w[i, j] * (Ps[i] + np.outer(dx, dx))
    # -- model-conditioned filtering + likelihoods --
    xs_new = np.zeros((K, n))
    Ps_new = np.zeros((K, n, n))
    loglik = np.zeros(K)
    for k, model in enumerate(imm.models):
        x_pred, P_pred = predict(model, x_mix[k], P_mix[k])
        if not has_z:
            xs_new[k], Ps_new[k] = x_pred, P_pred
            continue
        H = np.asarray(model.H, np.float64)
        R = np.asarray(model.R, np.float64)
        y = np.asarray(z, np.float64) - H @ x_pred
        S = H @ P_pred @ H.T + R
        loglik[k] = -0.5 * (y @ np.linalg.solve(S, y)
                            + np.log(np.linalg.det(S))
                            + m * np.log(2.0 * np.pi))
        xs_new[k], Ps_new[k] = update(model, x_pred, P_pred, z)
    # -- mode posterior (shift-stable; coasting keeps the prediction) --
    if has_z:
        wk = cbar * np.exp(loglik - loglik.max())
        mu_new = wk / wk.sum()
    else:
        mu_new = cbar
    x_c = mu_new @ xs_new
    return xs_new, Ps_new, mu_new, x_c


def run_imm(imm, zs: np.ndarray, x0=None, P0=None, mu0=None, valid=None):
    """IMM-filter a (T, m) measurement sequence.

    ``valid``, if given, is a (T,) boolean mask — False frames coast
    (predict only, mu <- cbar). Returns (combined states (T, n), mode
    probabilities (T, K))."""
    K = len(imm.models)
    x = np.tile(np.asarray(imm.x0 if x0 is None else x0, np.float64), (K, 1))
    P = np.tile(np.asarray(imm.P0 if P0 is None else P0, np.float64),
                (K, 1, 1))
    mu = np.asarray(imm.mu0 if mu0 is None else mu0, np.float64)
    out = np.zeros((len(zs), imm.n))
    mus = np.zeros((len(zs), K))
    for t, z in enumerate(zs):
        has_z = True if valid is None else bool(valid[t])
        x, P, mu, x_c = imm_step(imm, x, P, mu, z, has_z=has_z)
        out[t] = x_c
        mus[t] = mu
    return out, mus


def run_imm_batched(imm, zs: np.ndarray, x0: np.ndarray, P0: np.ndarray,
                    valid=None):
    """zs: (T, N, m); x0: (N, n); P0: (N, n, n) -> combined (T, N, n)
    and mode probabilities (T, N, K), each track an independent IMM.
    ``valid``: optional (T, N) boolean coasting mask (see run_imm)."""
    T, N, _ = zs.shape
    K = len(imm.models)
    out = np.zeros((T, N, imm.n))
    mus = np.zeros((T, N, K))
    for k in range(N):
        out[:, k], mus[:, k] = run_imm(
            imm, zs[:, k], x0=x0[k], P0=P0[k],
            valid=None if valid is None else valid[:, k])
    return out, mus
