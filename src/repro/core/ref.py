"""float64 numpy oracle for every KATANA stage and kernel.

This is the ground-truth Kalman recursion, written in the clearest
possible form with no performance concerns. All rewrite stages
(baseline / opt1 / opt2 / batched-blockdiag / batched-lanes) and the
Pallas ``katana_bank`` kernel must match it to fp32 tolerance.
"""
from __future__ import annotations

import numpy as np

from repro.core.filters import FilterModel


def predict(model: FilterModel, x: np.ndarray, P: np.ndarray):
    x = np.asarray(x, np.float64)
    P = np.asarray(P, np.float64)
    if model.is_linear:
        F = np.asarray(model.F, np.float64)
        x_pred = F @ x
    else:
        x_pred = model.f_np(x)
        F = model.F_jac_np(x)
    P_pred = F @ P @ F.T + np.asarray(model.Q, np.float64)
    return x_pred, P_pred


def update(model: FilterModel, x_pred: np.ndarray, P_pred: np.ndarray,
           z: np.ndarray):
    H = np.asarray(model.H, np.float64)
    R = np.asarray(model.R, np.float64)
    y = np.asarray(z, np.float64) - H @ x_pred
    S = H @ P_pred @ H.T + R
    K = P_pred @ H.T @ np.linalg.inv(S)
    x_new = x_pred + K @ y
    P_new = (np.eye(model.n) - K @ H) @ P_pred
    P_new = 0.5 * (P_new + P_new.T)
    return x_new, P_new


def step(model: FilterModel, x: np.ndarray, P: np.ndarray, z: np.ndarray):
    return update(model, *predict(model, x, P), z)


def run(model: FilterModel, zs: np.ndarray, x0=None, P0=None):
    """Filter a (T, m) measurement sequence; returns (T, n) states."""
    x = np.asarray(model.x0 if x0 is None else x0, np.float64)
    P = np.asarray(model.P0 if P0 is None else P0, np.float64)
    out = np.zeros((len(zs), model.n))
    covs = np.zeros((len(zs), model.n, model.n))
    for t, z in enumerate(zs):
        x, P = step(model, x, P, z)
        out[t] = x
        covs[t] = P
    return out, covs


def run_batched(model: FilterModel, zs: np.ndarray, x0: np.ndarray,
                P0: np.ndarray):
    """zs: (T, N, m); x0: (N, n); P0: (N, n, n) -> (T, N, n)."""
    T, N, _ = zs.shape
    out = np.zeros((T, N, model.n))
    xs = np.array(x0, np.float64)
    Ps = np.array(P0, np.float64)
    for t in range(T):
        for k in range(N):
            xs[k], Ps[k] = step(model, xs[k], Ps[k], zs[t, k])
        out[t] = xs
    return out, xs, Ps
