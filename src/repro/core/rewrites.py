"""KATANA's three NPU-aware graph rewrites, adapted to XLA/TPU.

Four stage builders mirror the paper's Fig. 3 pipeline, plus the
TPU-native beyond-paper batching:

  ``baseline``          naive export: runtime Subtract, runtime
                        Transpose of system matrices (passed as runtime
                        tensors, exactly like un-folded ONNX
                        initializers), dummy batch axes with
                        Unsqueeze/Squeeze bookkeeping, generic
                        ``linalg.inv``.
  ``opt1``              Subtract elimination: the precomputed
                        negative-projection matrix ``H_neg`` turns every
                        innovation/covariance subtraction into a GEMM +
                        Add (paper §IV-B).
  ``opt2``              Static tensor fusion: all system matrices and
                        their transposes folded as trace-time constants,
                        dummy axes removed, closed-form cofactor
                        inversion — the steady-state graph is dot/add
                        only (paper §IV-C).
  ``batched_blockdiag`` Paper §IV-D: N filters packed into one
                        (N·n)x(N·n) block-diagonal system; dense GEMMs.
                        Faithful reproduction — including its N^2 FLOP
                        expansion on covariance GEMMs.
  ``batched_lanes``     Beyond-paper TPU-native batching: filter index
                        on the minor (lane) axis, per-filter n x n
                        algebra batched via einsum; identical numerics
                        at ~N^2 less compute. This is the layout the
                        ``katana_bank`` Pallas kernel implements.
  ``fused_scan``        Sequence-level Opt-2: the whole (T, N, m)
                        measurement stream through ONE Pallas dispatch
                        (``katana_bank_sequence``) — the time loop runs
                        inside the kernel with x/P VMEM-resident across
                        frames, instead of a per-frame pallas_call with
                        the covariance bank bouncing through HBM.
  ``imm_bank``          Multi-model (IMM) estimation on the fused
                        kernel: K motion hypotheses per track run as
                        stacked lanes of one padded bank (the §IV-D
                        batching axis reused for the model index), the
                        per-lane kernel also emits the measurement
                        log-likelihood from the SAME cofactor S^{-1} it
                        computed for the Kalman gain, and the IMM
                        mixing / mode-probability algebra (this module)
                        closes the loop between frames — no inversion
                        anywhere outside the kernel.
  ``imm_scan``          Sequence-level IMM fusion: the mixing and
                        mode-posterior algebra move INSIDE the scan
                        kernel's time loop, so a whole K-hypothesis
                        stream over T frames is ONE Pallas dispatch with
                        x/P and the mode probabilities VMEM-resident
                        across frames (``make_imm_scan_kernel`` /
                        ``katana_imm_sequence``). The Markov transition
                        matrix and every per-model constant fold at
                        trace time; K=1 reduces exactly (bitwise) to
                        ``fused_scan``.

Every stage is algebraically the same filter (``imm_bank``/``imm_scan``
with K=1 degenerate to it exactly); tests assert equivalence against
the float64 oracles in ``repro.core.ref``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import FilterModel, IMMModel, as_imm

STAGES = ("baseline", "opt1", "opt2", "batched_blockdiag", "batched_lanes",
          "fused_scan", "imm_bank", "imm_scan")


# ---------------------------------------------------------------------------
# Closed-form small-matrix inversion (cofactor / Schur), batched-friendly.
# Pure mul/add + one reciprocal — the TPU analogue of the paper's §IV-C
# replacement of the generic inversion op, keeping the whole update on
# the matrix pipeline (see docs/architecture.md).
# ---------------------------------------------------------------------------

def inv1(M):
    return 1.0 / M


def inv2(M):
    a = M[..., 0, 0]
    b = M[..., 0, 1]
    c = M[..., 1, 0]
    d = M[..., 1, 1]
    rdet = 1.0 / (a * d - b * c)
    row0 = jnp.stack([d * rdet, -b * rdet], axis=-1)
    row1 = jnp.stack([-c * rdet, a * rdet], axis=-1)
    return jnp.stack([row0, row1], axis=-2)


def inv3(M):
    m = [[M[..., i, j] for j in range(3)] for i in range(3)]
    c00 = m[1][1] * m[2][2] - m[1][2] * m[2][1]
    c01 = m[1][2] * m[2][0] - m[1][0] * m[2][2]
    c02 = m[1][0] * m[2][1] - m[1][1] * m[2][0]
    c10 = m[0][2] * m[2][1] - m[0][1] * m[2][2]
    c11 = m[0][0] * m[2][2] - m[0][2] * m[2][0]
    c12 = m[0][1] * m[2][0] - m[0][0] * m[2][1]
    c20 = m[0][1] * m[1][2] - m[0][2] * m[1][1]
    c21 = m[0][2] * m[1][0] - m[0][0] * m[1][2]
    c22 = m[0][0] * m[1][1] - m[0][1] * m[1][0]
    rdet = 1.0 / (m[0][0] * c00 + m[0][1] * c01 + m[0][2] * c02)
    rows = [
        jnp.stack([c00, c10, c20], axis=-1),
        jnp.stack([c01, c11, c21], axis=-1),
        jnp.stack([c02, c12, c22], axis=-1),
    ]
    return jnp.stack(rows, axis=-2) * rdet[..., None, None]


def inv4(M):
    """2x2-block Schur-complement inversion; mul/add + inv2 reciprocals."""
    A = M[..., :2, :2]
    B = M[..., :2, 2:]
    C = M[..., 2:, :2]
    D = M[..., 2:, 2:]
    Di = inv2(D)
    BDi = B @ Di
    S = A - BDi @ C  # Schur complement
    Si = inv2(S)
    SiBDi = Si @ BDi
    DiC = Di @ C
    top = jnp.concatenate([Si, -SiBDi], axis=-1)
    bot = jnp.concatenate([-DiC @ Si, Di + DiC @ SiBDi], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


_SMALL_INV = {1: inv1, 2: inv2, 3: inv3, 4: inv4}


@functools.lru_cache(maxsize=None)
def triu_pack(n: int):
    """Upper-triangle packing plan for symmetric (..., n, n) einsum
    products — the einsum-stage analogue of the kernels'
    symmetrize=True triangle emission (ROADMAP item): compute only the
    n(n+1)/2 upper entries and reconstitute the full matrix by ALIASING
    the mirrors (exact symmetry, no averaging pass), cutting the
    dominant second contraction of F·P·Fᵀ-shaped products by
    ~n(n-1)/2n² ≈ 44% for n=9.

    Returns (rows, cols, mirror): ``rows``/``cols`` index the packed
    (i <= j) entries; ``mirror[i, j]`` is the packed index of
    (min(i,j), max(i,j)), so ``tri[..., mirror]`` is the one gather
    that unpacks a (..., T) triangle into the (..., n, n) symmetric
    matrix."""
    rows, cols = np.triu_indices(n)
    mirror = np.zeros((n, n), np.int32)
    for t, (i, j) in enumerate(zip(rows, cols)):
        mirror[i, j] = mirror[j, i] = t
    return rows, cols, mirror


def sym_unpack(tri, n: int):
    """(..., n(n+1)/2) packed upper triangle -> (..., n, n) symmetric
    matrix with aliased mirrors (see ``triu_pack``)."""
    _, _, mirror = triu_pack(n)
    return tri[..., mirror]


def small_inv(M, dim: int):
    if dim in _SMALL_INV:
        return _SMALL_INV[dim](M)
    return jnp.linalg.inv(M)  # general fallback (not used by the paper dims)


def small_det(M, dim: int):
    """Closed-form determinant of a (..., dim, dim) batch, dim <= 4 —
    pure mul/add (cofactor expansion; Schur product for dim=4), no
    factorization. Used for the IMM mode likelihoods: the Gaussian
    normalizer needs det(S), and this keeps it on the same
    matrix-pipeline discipline as ``small_inv`` (paper §IV-C)."""
    if dim == 1:
        return M[..., 0, 0]
    if dim == 2:
        return M[..., 0, 0] * M[..., 1, 1] - M[..., 0, 1] * M[..., 1, 0]
    if dim == 3:
        m = [[M[..., i, j] for j in range(3)] for i in range(3)]
        return (m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
                + m[0][1] * (m[1][2] * m[2][0] - m[1][0] * m[2][2])
                + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0]))
    if dim == 4:
        D = M[..., 2:, 2:]
        S = M[..., :2, :2] - M[..., :2, 2:] @ inv2(D) @ M[..., 2:, :2]
        return small_det(D, 2) * small_det(S, 2)
    return jnp.linalg.det(M)


# ---------------------------------------------------------------------------
# IMM mixing / mode-probability algebra (the "imm_bank" stage glue).
# Shared by the tracker bank (repro.core.bank), the kernel sequence
# runner (repro.kernels.katana_bank.ops) and the jnp oracle. Everything
# is einsum/mul/add over a (K, B, ...) model-major layout — the same
# static-shape discipline as the rest of the stage ladder.
# ---------------------------------------------------------------------------

_LOG_2PI = float(np.log(2.0 * np.pi))


def imm_mix(x, P, mu, Pi):
    """IMM interaction (mixing) step.

    x: (K, B, n) model-conditioned means; P: (K, B, n, n); mu: (B, K)
    mode probabilities; Pi: (K, K) row-stochastic transition matrix.
    Returns (x_mix (K, B, n), P_mix (K, B, n, n), cbar (B, K)) where
    cbar[b, j] = sum_i mu[b, i] Pi[i, j] is the predicted mode
    probability. The spread term (x_i - x_mix_j)(x_i - x_mix_j)^T keeps
    P_mix consistent (and PSD) under mode disagreement.
    """
    cbar = mu @ Pi                                           # (B, K)
    # cbar_j = 0 (a mode the chain cannot reach, e.g. an identity
    # transition with mu_j = 0) would divide 0/0 here; clamping the
    # denominator keeps w finite and exactly 0 for that column, and the
    # dead mode's posterior weight stays 0 via cbar in
    # imm_mode_posterior — no NaN ever enters the track state.
    cbar_safe = jnp.maximum(cbar, jnp.finfo(cbar.dtype).tiny)
    w = mu[:, :, None] * Pi[None, :, :] / cbar_safe[:, None, :]  # (B, i, j)
    x_mix = jnp.einsum("bij,ibd->jbd", w, x)
    dx = x[:, None] - x_mix[None, :]                         # (i, j, B, n)
    P_mix = (jnp.einsum("bij,ibuv->jbuv", w, P)
             + jnp.einsum("bij,ijbu,ijbv->jbuv", w, dx, dx))
    return x_mix, P_mix, cbar


def imm_mode_posterior(cbar, loglik):
    """Mode-probability update: mu'_k ∝ cbar_k exp(loglik_k), computed
    shift-stably (the max log-likelihood is subtracted before exp, so
    at least one mode always contributes a finite weight).

    cbar: (B, K); loglik: (K, B) per-mode measurement log-likelihoods.
    Returns mu' (B, K), rows summing to 1."""
    ll = jnp.swapaxes(loglik, 0, 1)                          # (B, K)
    w = cbar * jnp.exp(ll - ll.max(axis=1, keepdims=True))
    return w / w.sum(axis=1, keepdims=True)


def imm_combine(x, P, mu):
    """Moment-matched combined estimate: x_c = sum_k mu_k x_k and the
    mixture covariance with the spread term.

    x: (K, B, n); P: (K, B, n, n); mu: (B, K) -> (x_c (B, n),
    P_c (B, n, n))."""
    x_c = jnp.einsum("bk,kbd->bd", mu, x)
    dx = x - x_c[None]                                       # (K, B, n)
    P_c = (jnp.einsum("bk,kbuv->buv", mu, P)
           + jnp.einsum("bk,kbu,kbv->buv", mu, dx, dx))
    return x_c, P_c


def gaussian_loglik(y, Sinv, logdetS, m: int):
    """log N(y; 0, S) from the innovation y (..., m), the precomputed
    cofactor inverse Sinv (..., m, m) and log det S (...). No inversion
    happens here — the whole point is to reuse the S^{-1} the Kalman
    gain already paid for (predict_bank / the kernel's emitted Sinv)."""
    d = jnp.einsum("...u,...uv,...v->...", y, Sinv, y)
    return -0.5 * (d + logdetS + m * _LOG_2PI)


# ---------------------------------------------------------------------------
# Stage constants
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageConstants:
    """Trace-time constants for opt1+ stages (paper's graph initializers)."""

    F: jnp.ndarray
    FT: jnp.ndarray
    H: jnp.ndarray
    HT: jnp.ndarray
    H_neg: jnp.ndarray
    H_negT: jnp.ndarray
    Q: jnp.ndarray
    R: jnp.ndarray
    I_n: jnp.ndarray


def stage_constants(model: FilterModel, dtype=jnp.float32) -> StageConstants:
    F = jnp.asarray(model.F, dtype)
    H = jnp.asarray(model.H, dtype)
    return StageConstants(
        F=F, FT=F.T, H=H, HT=H.T, H_neg=-H, H_negT=(-H).T,
        Q=jnp.asarray(model.Q, dtype), R=jnp.asarray(model.R, dtype),
        I_n=jnp.eye(model.n, dtype=dtype),
    )


def block_diag_batched(blocks: jnp.ndarray) -> jnp.ndarray:
    """(N, a, b) -> (N*a, N*b) block-diagonal (paper §IV-D expansion)."""
    N, a, b = blocks.shape
    out = jnp.zeros((N, a, N, b), blocks.dtype)
    idx = jnp.arange(N)
    out = out.at[idx, :, idx, :].set(blocks)
    return out.reshape(N * a, N * b)


def block_diag_const(M: np.ndarray, N: int) -> np.ndarray:
    """kron(I_N, M): replicate one block N times on the diagonal."""
    return np.kron(np.eye(N), M)


# ---------------------------------------------------------------------------
# Stage builders. Each returns step(x, P, z, sys?) -> (x, P) with the
# state layout documented per stage.
# ---------------------------------------------------------------------------

def build_baseline(model: FilterModel, dtype=jnp.float32,
                   symmetrize: bool = False) -> Tuple[Callable, Dict]:
    """Naive export. State: x (1, n, 1); P (1, n, n); z (1, m, 1).

    System matrices are *runtime tensors* (like un-folded initializers),
    so the Transposes, Subtracts and the generic inversion are real ops
    in the lowered graph — this is the graph the paper's Fig. 3 calls
    Baseline.
    """
    n, m = model.n, model.m
    sys = dict(
        F=jnp.asarray(model.F, dtype), H=jnp.asarray(model.H, dtype),
        Q=jnp.asarray(model.Q, dtype), R=jnp.asarray(model.R, dtype),
    )

    def step(x, P, z, sys=sys):
        F, H, Q, R = sys["F"], sys["H"], sys["Q"], sys["R"]
        # -- exporter-style shape bookkeeping (Squeeze/Unsqueeze/Reshape) --
        xs = jnp.reshape(x, (1, n))           # Squeeze
        if model.is_linear:
            x_pred = jnp.expand_dims(xs, -1)  # Unsqueeze
            x_pred = jnp.matmul(F, x_pred)    # (n,n)@(1,n,1)
        else:
            x_pred = jnp.expand_dims(model.predict_mean(xs), -1)
        Fk = model.jacobian(xs)               # (1, n, n)
        P_pred = jnp.matmul(jnp.matmul(Fk, P), jnp.transpose(Fk, (0, 2, 1))) + Q
        # -- innovation with runtime Subtract (the op the NPU's DSP eats) --
        y = z - jnp.matmul(H, x_pred)
        S = jnp.matmul(jnp.matmul(H, P_pred), jnp.transpose(H)) + R
        K = jnp.matmul(jnp.matmul(P_pred, jnp.transpose(H)), jnp.linalg.inv(S))
        x_new = x_pred + jnp.matmul(K, y)
        I = jnp.eye(n, dtype=dtype)
        P_new = jnp.matmul(I - jnp.matmul(K, H), P_pred)
        if symmetrize:
            P_new = 0.5 * (P_new + jnp.transpose(P_new, (0, 2, 1)))
        return jnp.reshape(x_new, (1, n, 1)), P_new

    meta = dict(stage="baseline", layout="dummy-batch", n=n, m=m)
    return step, meta


def build_opt1(model: FilterModel, dtype=jnp.float32,
               symmetrize: bool = False) -> Tuple[Callable, Dict]:
    """Subtract elimination (paper §IV-B). Same layout as baseline, but
    every ``a - b`` becomes ``a + neg(b)`` with the negation folded into
    a precomputed constant: H_neg for the innovation, and the covariance
    update rewritten ``P = P_pred + K (H_neg P_pred)``."""
    n, m = model.n, model.m
    sys = dict(
        F=jnp.asarray(model.F, dtype), H=jnp.asarray(model.H, dtype),
        H_neg=jnp.asarray(-model.H, dtype),
        Q=jnp.asarray(model.Q, dtype), R=jnp.asarray(model.R, dtype),
    )

    def step(x, P, z, sys=sys):
        F, H, H_neg = sys["F"], sys["H"], sys["H_neg"]
        Q, R = sys["Q"], sys["R"]
        xs = jnp.reshape(x, (1, n))
        if model.is_linear:
            x_pred = jnp.matmul(F, jnp.expand_dims(xs, -1))
        else:
            x_pred = jnp.expand_dims(model.predict_mean(xs), -1)
        Fk = model.jacobian(xs)
        P_pred = jnp.matmul(jnp.matmul(Fk, P), jnp.transpose(Fk, (0, 2, 1))) + Q
        # subtract-free innovation: z + H_neg x̂
        y = z + jnp.matmul(H_neg, x_pred)
        S = jnp.matmul(jnp.matmul(H, P_pred), jnp.transpose(H)) + R
        K = jnp.matmul(jnp.matmul(P_pred, jnp.transpose(H)), jnp.linalg.inv(S))
        x_new = x_pred + jnp.matmul(K, y)
        # subtract-free covariance: P + K (H_neg P)
        P_new = P_pred + jnp.matmul(K, jnp.matmul(H_neg, P_pred))
        if symmetrize:
            P_new = 0.5 * (P_new + jnp.transpose(P_new, (0, 2, 1)))
        return jnp.reshape(x_new, (1, n, 1)), P_new

    meta = dict(stage="opt1", layout="dummy-batch", n=n, m=m)
    return step, meta


def build_opt2(model: FilterModel, dtype=jnp.float32,
               symmetrize: bool = False) -> Tuple[Callable, Dict]:
    """Static tensor fusion (paper §IV-C). State: x (n,); P (n, n);
    z (m,). All system matrices and their transposes are trace-time
    constants; no dummy axes; cofactor inversion. The steady-state graph
    is exclusively dot/add/mul."""
    n, m = model.n, model.m
    C = stage_constants(model, dtype)

    def step(x, P, z):
        if model.is_linear:
            x_pred = C.F @ x
            P_pred = C.F @ P @ C.FT + C.Q
        else:
            x_pred = model.predict_mean(x)
            Fk = model.jacobian(x)
            P_pred = Fk @ P @ jnp.swapaxes(Fk, -1, -2) + C.Q
        y = z + C.H_neg @ x_pred
        PHt = P_pred @ C.HT
        S = C.H @ PHt + C.R
        K = PHt @ small_inv(S, m)
        x_new = x_pred + K @ y
        P_new = P_pred + K @ (C.H_neg @ P_pred)
        if symmetrize:
            P_new = 0.5 * (P_new + jnp.swapaxes(P_new, -1, -2))
        return x_new, P_new

    meta = dict(stage="opt2", layout="flat", n=n, m=m)
    return step, meta


def build_batched_blockdiag(model: FilterModel, N: int, dtype=jnp.float32,
                            symmetrize: bool = False) -> Tuple[Callable, Dict]:
    """Paper §IV-D, faithful: expand every per-filter matrix into an
    (N·n)x(N·n) block-diagonal system matrix and run ONE dense GEMM
    chain per step. State: x (N*n,); P (N*n, N*n); z (N*m,).

    For the LKF all block-diagonal system matrices are constants
    (folded, like the paper's ONNX initializers). For the EKF the
    Jacobian blocks are rebuilt each step and scattered onto the
    diagonal, exactly as the paper rebuilds its per-frame Jacobians.
    The S inversion is performed blockwise (cofactor) and scattered
    back to dense — the paper keeps "a single inversion" per recursion;
    a dense (N·m) inversion would change the numerics class, a
    blockwise one is exact.
    """
    n, m = model.n, model.m
    Nn, Nm = N * n, N * m
    F_bd = jnp.asarray(block_diag_const(model.F, N), dtype)
    FT_bd = F_bd.T
    H_bd = jnp.asarray(block_diag_const(model.H, N), dtype)
    HT_bd = H_bd.T
    Hneg_bd = -H_bd
    Q_bd = jnp.asarray(block_diag_const(model.Q, N), dtype)
    R_blocks = jnp.broadcast_to(jnp.asarray(model.R, dtype), (N, m, m))
    R_bd = block_diag_batched(R_blocks)

    def step(x, P, z):
        if model.is_linear:
            x_pred = F_bd @ x
            P_pred = F_bd @ P @ FT_bd + Q_bd  # dense (Nn)^3 GEMMs — the
            # paper's N^2 FLOP expansion, kept faithfully.
        else:
            xs = x.reshape(N, n)
            x_pred = model.predict_mean(xs).reshape(Nn)
            Fk_bd = block_diag_batched(model.jacobian(xs))
            P_pred = Fk_bd @ P @ Fk_bd.T + Q_bd
        y = z + Hneg_bd @ x_pred
        PHt = P_pred @ HT_bd
        S = H_bd @ PHt + R_bd  # (Nm, Nm), block-diagonal by construction
        S_blocks = extract_diag_blocks(S, N, m)
        Sinv_bd = block_diag_batched(small_inv(S_blocks, m))
        K = PHt @ Sinv_bd
        x_new = x_pred + K @ y
        P_new = P_pred + K @ (Hneg_bd @ P_pred)
        if symmetrize:
            P_new = 0.5 * (P_new + P_new.T)
        return x_new, P_new

    meta = dict(stage="batched_blockdiag", layout="blockdiag", n=n, m=m, N=N)
    return step, meta


def extract_diag_blocks(M: jnp.ndarray, N: int, b: int) -> jnp.ndarray:
    """(N*b, N*b) -> (N, b, b) diagonal blocks."""
    M4 = M.reshape(N, b, N, b)
    idx = jnp.arange(N)
    return M4[idx, :, idx, :]


def build_batched_lanes(model: FilterModel, N: int, dtype=jnp.float32,
                        symmetrize: bool = False) -> Tuple[Callable, Dict]:
    """Beyond-paper TPU-native batching: the filter index k lives on the
    minor (lane) axis and the per-filter n x n algebra is batched via
    einsum. State: x (N, n); P (N, n, n); z (N, m). Identical numerics
    to ``batched_blockdiag`` at ~N^2 less covariance compute; this is
    the reference semantics for the ``katana_bank`` Pallas kernel.

    Under ``symmetrize`` the covariance products are emitted
    upper-triangle-only with aliased mirrors (``triu_pack``), the same
    contract as the kernels' symmetrize=True: exact symmetry at
    n(n+1)/2 instead of n² second-contraction dots, no averaging pass.
    ``symmetrize=False`` keeps the faithful full-square emission
    (asymmetry of the float product preserved) for blockdiag
    equivalence."""
    n, m = model.n, model.m
    C = stage_constants(model, dtype)
    iu, ju, _ = triu_pack(n)

    def step(x, P, z):
        if model.is_linear:
            x_pred = jnp.einsum("ij,kj->ki", C.F, x)
            FP = jnp.einsum("ij,kjl->kil", C.F, P)
            if symmetrize:
                P_pred = sym_unpack(
                    jnp.einsum("ktl,tl->kt", FP[:, iu, :], C.F[ju, :])
                    + C.Q[iu, ju], n)
            else:
                P_pred = jnp.einsum("kil,jl->kij", FP, C.F) + C.Q
        else:
            x_pred = model.predict_mean(x)
            Fk = model.jacobian(x)  # (N, n, n)
            FP = jnp.einsum("kij,kjl->kil", Fk, P)
            if symmetrize:
                P_pred = sym_unpack(
                    jnp.einsum("ktl,ktl->kt", FP[:, iu, :], Fk[:, ju, :])
                    + C.Q[iu, ju], n)
            else:
                P_pred = jnp.einsum("kil,kjl->kij", FP, Fk) + C.Q
        y = z + jnp.einsum("mi,ki->km", C.H_neg, x_pred)
        PHt = jnp.einsum("kij,mj->kim", P_pred, C.H)
        S = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
        K = jnp.einsum("kim,kmn->kin", PHt, small_inv(S, m))
        x_new = x_pred + jnp.einsum("kin,kn->ki", K, y)
        HnP = jnp.einsum("mi,kij->kmj", C.H_neg, P_pred)
        if symmetrize:
            P_new = sym_unpack(
                P_pred[:, iu, ju]
                + jnp.einsum("ktm,kmt->kt", K[:, iu, :], HnP[:, :, ju]), n)
        else:
            P_new = P_pred + jnp.einsum("kim,kmj->kij", K, HnP)
        return x_new, P_new

    meta = dict(stage="batched_lanes", layout="batched", n=n, m=m, N=N)
    return step, meta


def build_fused_scan(model: FilterModel, N: int, dtype=jnp.float32,
                     symmetrize: bool = False) -> Tuple[Callable, Dict]:
    """The Pallas ``katana_bank`` kernel as a stage. State: x (N, n);
    P (N, n, n); z (N, m) — canonical layout, same as batched_lanes.

    The per-step view dispatches the fused single-frame kernel; the
    sequence view (``run_sequence``) dispatches ONE multi-frame scan
    kernel for the whole stream — see
    ``repro.kernels.katana_bank.ops.katana_bank_sequence``. The kernel
    computes in f32 lanes regardless of ``dtype``.
    """
    from repro.kernels.katana_bank.ops import katana_bank

    n, m = model.n, model.m

    def step(x, P, z):
        return katana_bank(model, x, P, z, symmetrize=symmetrize)

    meta = dict(stage="fused_scan", layout="batched", n=n, m=m, N=N)
    return step, meta


def build_imm_bank(model, N: int, dtype=jnp.float32,
                   symmetrize: bool = True) -> Tuple[Callable, Dict]:
    """The IMM multi-model bank as a stage. A plain FilterModel is
    wrapped as a degenerate K=1 IMM (``as_imm``), so every single-model
    workload is also a valid imm_bank workload.

    Unlike the other stages the step carries mode probabilities:
    ``step(x (K, N, n), P (K, N, n, n), z (N, m), mu (N, K)) ->
    (x', P', mu')`` — one IMM cycle: mix -> fused multi-model kernel
    (predict+update+log-likelihood, stacked lanes) -> mode posterior.
    ``run_sequence`` adapts it to the canonical (N, n) layout by
    combining the per-model estimates each frame.
    """
    from repro.kernels.katana_bank.ops import katana_bank_imm

    imm = as_imm(model)
    Pi = jnp.asarray(imm.trans, dtype)

    def step(x, P, z, mu):
        x_mix, P_mix, cbar = imm_mix(x, P, mu, Pi)
        x_new, P_new, loglik = katana_bank_imm(imm, x_mix, P_mix, z,
                                               symmetrize=symmetrize)
        mu_new = imm_mode_posterior(cbar, loglik)
        return x_new, P_new, mu_new

    meta = dict(stage="imm_bank", layout="model-major", n=imm.n, m=imm.m,
                N=N, K=imm.K)
    return step, meta


def build_imm_scan(model, N: int, dtype=jnp.float32,
                   symmetrize: bool = True) -> Tuple[Callable, Dict]:
    """The fused IMM scan as a stage: same step signature as
    ``imm_bank`` (``step(x, P, z, mu) -> (x', P', mu')``), but the whole
    cycle — mixing, the K predict+updates, the mode posterior — runs
    inside ONE scan-kernel dispatch (at T=1 here; ``run_sequence``
    dispatches the whole stream at once). K=1 reduces exactly to
    ``fused_scan``."""
    from repro.kernels.katana_bank.ops import katana_imm_sequence

    imm = as_imm(model)

    def step(x, P, z, mu):
        _, (x2, P2, mu2) = katana_imm_sequence(
            imm, z[None], x, P, mu0=mu, symmetrize=symmetrize,
            return_final=True)
        return x2, P2, mu2

    meta = dict(stage="imm_scan", layout="model-block", n=imm.n, m=imm.m,
                N=N, K=imm.K)
    return step, meta


def build_stage(model: FilterModel, stage: str, N: Optional[int] = None,
                dtype=jnp.float32, symmetrize: bool = False):
    """Uniform entry point; returns (step, meta)."""
    if stage == "baseline":
        return build_baseline(model, dtype, symmetrize)
    if stage == "opt1":
        return build_opt1(model, dtype, symmetrize)
    if stage == "opt2":
        return build_opt2(model, dtype, symmetrize)
    if stage == "batched_blockdiag":
        assert N is not None
        return build_batched_blockdiag(model, N, dtype, symmetrize)
    if stage == "batched_lanes":
        assert N is not None
        return build_batched_lanes(model, N, dtype, symmetrize)
    if stage == "fused_scan":
        assert N is not None
        return build_fused_scan(model, N, dtype, symmetrize)
    if stage == "imm_bank":
        assert N is not None
        return build_imm_bank(model, N, dtype, symmetrize)
    if stage == "imm_scan":
        assert N is not None
        return build_imm_scan(model, N, dtype, symmetrize)
    raise KeyError(f"unknown stage {stage!r}; known: {STAGES}")


# ---------------------------------------------------------------------------
# Layout adapters: every stage exposes run_sequence() with the canonical
# (N, n) / (N, n, n) layout so tests and benches drive them uniformly.
# ---------------------------------------------------------------------------

def canonical_to_stage(stage: str, x, P, z, n: int, m: int):
    if stage in ("baseline", "opt1"):
        return x.reshape(1, n, 1), P.reshape(1, n, n), z.reshape(1, m, 1)
    if stage == "opt2":
        return x.reshape(n), P.reshape(n, n), z.reshape(m)
    if stage == "batched_blockdiag":
        N = x.shape[0]
        return x.reshape(N * n), block_diag_batched(P), z.reshape(N * m)
    return x, P, z  # batched_lanes / fused_scan are canonical


def stage_to_canonical(stage: str, x, P, n: int, m: int, N: int):
    if stage in ("baseline", "opt1"):
        return x.reshape(1, n), P.reshape(1, n, n)
    if stage == "opt2":
        return x.reshape(1, n), P.reshape(1, n, n)
    if stage == "batched_blockdiag":
        return x.reshape(N, n), extract_diag_blocks(P, N, n)
    return x, P


def run_sequence(model: FilterModel, stage: str, zs, x0, P0,
                 dtype=jnp.float32, symmetrize: bool = False):
    """Drive a stage over a (T, N, m) measurement sequence.

    x0: (N, n); P0: (N, n, n). N must be 1 for single-filter stages.
    Returns (T, N, n) filtered states (float32).
    """
    zs = jnp.asarray(zs, dtype)
    T, N, m = zs.shape
    n = model.n
    if stage in ("baseline", "opt1", "opt2"):
        assert N == 1, f"stage {stage} is single-filter"
    if stage == "fused_scan":
        # Sequence-native stage: one kernel dispatch for the whole
        # stream instead of a lax.scan over per-frame steps.
        from repro.kernels.katana_bank.ops import katana_bank_sequence

        return katana_bank_sequence(model, zs, jnp.asarray(x0, dtype),
                                    jnp.asarray(P0, dtype),
                                    symmetrize=symmetrize)
    if stage == "imm_bank":
        # Multi-model stage: (x0, P0) seed every mode identically; the
        # returned track is the moment-matched combined estimate.
        from repro.kernels.katana_bank.ops import imm_bank_sequence

        return imm_bank_sequence(as_imm(model), zs, jnp.asarray(x0, dtype),
                                 jnp.asarray(P0, dtype),
                                 symmetrize=symmetrize)
    if stage == "imm_scan":
        # Sequence-native multi-model stage: the whole stream (mixing
        # and mode posterior included) through one kernel dispatch.
        from repro.kernels.katana_bank.ops import katana_imm_sequence

        return katana_imm_sequence(as_imm(model), zs, jnp.asarray(x0, dtype),
                                   jnp.asarray(P0, dtype),
                                   symmetrize=symmetrize)
    step, _ = build_stage(model, stage, N=N, dtype=dtype, symmetrize=symmetrize)

    x, P, _ = canonical_to_stage(stage, jnp.asarray(x0, dtype),
                                 jnp.asarray(P0, dtype),
                                 jnp.zeros((N, m), dtype), n, m)

    def scan_body(carry, z_t):
        x, P = carry
        _, _, z_s = canonical_to_stage(stage, jnp.zeros((N, n), dtype),
                                       jnp.zeros((N, n, n), dtype), z_t, n, m)
        x, P = step(x, P, z_s)
        x_c, _ = stage_to_canonical(stage, x, P, n, m, N)
        return (x, P), x_c

    (_, _), xs = jax.lax.scan(scan_body, (x, P), zs)
    return xs
