"""Filter models: the paper's two workloads.

LKF — constant-velocity, n=6 state [px,py,pz,vx,vy,vz], m=3 position
measurements (paper §V: "3-D position and velocity").

EKF — constant-turn-rate-with-acceleration, n=8 state
[px,py,pz,v,theta,omega,a,vz], m=4 measurements [px,py,pz,theta]
(paper §V: "constant-turn-rate with acceleration"). The dynamics are
nonlinear (the EKF linearizes via the Jacobian F_k each step); the
measurement map stays linear so the H_neg rewrite applies verbatim.

All matrices are built once at model-construction time, mirroring the
paper's constant folding: anything static (F, H, H_neg, their
transposes, Q, R, I) is a trace-time constant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True, eq=False)  # identity hash: usable as jit static arg
class FilterModel:
    """A (possibly nonlinear-dynamics) filter with linear measurements."""

    name: str
    n: int  # state dim
    m: int  # measurement dim
    is_linear: bool
    F: np.ndarray  # (n,n) — LKF transition (EKF: linearization point 0)
    H: np.ndarray  # (m,n) — measurement matrix (linear for both workloads)
    Q: np.ndarray  # (n,n) process noise
    R: np.ndarray  # (m,m) measurement noise
    x0: np.ndarray  # (n,) default initial state
    P0: np.ndarray  # (n,n) default initial covariance
    dt: float = 1.0 / 30.0
    # Nonlinear dynamics (EKF): f(x)->x', jac(x)->(n,n). None for LKF.
    f: Optional[Callable] = None
    F_jac: Optional[Callable] = None
    # Pure-numpy float64 mirrors for the oracle in ref.py.
    f_np: Optional[Callable] = None
    F_jac_np: Optional[Callable] = None

    def predict_mean(self, x):
        """Propagate the state mean (works on jnp arrays, batched or not)."""
        if self.is_linear:
            return x @ jnp.asarray(self.F, x.dtype).T
        return self.f(x)

    def jacobian(self, x):
        """(.., n, n) transition Jacobian at x."""
        if self.is_linear:
            F = jnp.asarray(self.F, x.dtype)
            return jnp.broadcast_to(F, x.shape[:-1] + (self.n, self.n))
        return self.F_jac(x)


def make_cv_lkf(dt: float = 1.0 / 30.0, q: float = 1e-2, r: float = 1e-1,
                p0: float = 1.0) -> FilterModel:
    """3-D constant-velocity LKF (paper's n=6 workload)."""
    n, m = 6, 3
    F = np.eye(n)
    F[:3, 3:] = dt * np.eye(3)
    H = np.zeros((m, n))
    H[:, :3] = np.eye(3)
    # Discretized white-noise-acceleration process covariance.
    G = np.zeros((n, 3))
    G[:3] = 0.5 * dt * dt * np.eye(3)
    G[3:] = dt * np.eye(3)
    Q = q * (G @ G.T) + 1e-9 * np.eye(n)
    R = r * np.eye(m)
    return FilterModel(
        name="lkf-cv6", n=n, m=m, is_linear=True, F=F, H=H, Q=Q, R=R,
        x0=np.zeros(n), P0=p0 * np.eye(n), dt=dt,
    )


def make_ctra_ekf(dt: float = 1.0 / 30.0, q: float = 1e-2, r: float = 1e-1,
                  p0: float = 1.0) -> FilterModel:
    """Constant-turn-rate + acceleration EKF (paper's n=8 workload).

    State: [px, py, pz, v, theta, omega, a, vz]; first-order discretized
    CTRA dynamics (no omega->0 singularity; pure mul/add + sin/cos, in
    the paper's spirit of keeping the graph on the matrix/vector units).
    """
    n, m = 8, 4

    def f(x):
        px, py, pz, v, th, om, a, vz = [x[..., i] for i in range(n)]
        c, s = jnp.cos(th), jnp.sin(th)
        return jnp.stack(
            [
                px + v * c * dt,
                py + v * s * dt,
                pz + vz * dt,
                v + a * dt,
                th + om * dt,
                om,
                a,
                vz,
            ],
            axis=-1,
        )

    def F_jac(x):
        v, th = x[..., 3], x[..., 4]
        c, s = jnp.cos(th), jnp.sin(th)
        batch = x.shape[:-1]
        F = jnp.broadcast_to(jnp.eye(n, dtype=x.dtype), batch + (n, n))
        upd = {
            (0, 3): c * dt, (0, 4): -v * s * dt,
            (1, 3): s * dt, (1, 4): v * c * dt,
            (2, 7): jnp.full(batch, dt, x.dtype),
            (3, 6): jnp.full(batch, dt, x.dtype),
            (4, 5): jnp.full(batch, dt, x.dtype),
        }
        for (i, j), val in upd.items():
            F = F.at[..., i, j].set(val)
        return F

    def f_np(x):
        x = np.asarray(x, np.float64)
        px, py, pz, v, th, om, a, vz = x
        c, s = np.cos(th), np.sin(th)
        return np.array(
            [px + v * c * dt, py + v * s * dt, pz + vz * dt, v + a * dt,
             th + om * dt, om, a, vz], np.float64)

    def F_jac_np(x):
        x = np.asarray(x, np.float64)
        v, th = x[3], x[4]
        c, s = np.cos(th), np.sin(th)
        F = np.eye(n)
        F[0, 3] = c * dt
        F[0, 4] = -v * s * dt
        F[1, 3] = s * dt
        F[1, 4] = v * c * dt
        F[2, 7] = dt
        F[3, 6] = dt
        F[4, 5] = dt
        return F

    H = np.zeros((m, n))
    H[0, 0] = H[1, 1] = H[2, 2] = 1.0  # position
    H[3, 4] = 1.0  # heading
    Q = q * np.eye(n)
    Q[5, 5] = Q[6, 6] = q * 0.1  # slowly-varying turn-rate / accel
    R = r * np.eye(m)
    x0 = np.zeros(n)
    x0[3] = 1.0  # unit speed so the Jacobian is non-degenerate at init
    # Linearization point for the "F" constant: Jacobian at x0.
    F0 = np.eye(n)
    F0[0, 3] = dt
    F0[1, 4] = dt
    F0[2, 7] = dt
    F0[3, 6] = dt
    F0[4, 5] = dt
    return FilterModel(
        name="ekf-ctra8", n=n, m=m, is_linear=False, F=F0, H=H, Q=Q, R=R,
        x0=x0, P0=p0 * np.eye(n), dt=dt, f=f, F_jac=F_jac,
        f_np=f_np, F_jac_np=F_jac_np,
    )


def get_filter(kind: str, dt: float = 1.0 / 30.0) -> FilterModel:
    if kind == "lkf":
        return make_cv_lkf(dt=dt)
    if kind == "ekf":
        return make_ctra_ekf(dt=dt)
    raise KeyError(f"unknown filter kind {kind!r}")
