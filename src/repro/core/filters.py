"""Filter models: the paper's two workloads plus the IMM model set.

LKF — constant-velocity, n=6 state [px,py,pz,vx,vy,vz], m=3 position
measurements (paper §V: "3-D position and velocity").

EKF — constant-turn-rate-with-acceleration, n=8 state
[px,py,pz,v,theta,omega,a,vz], m=4 measurements [px,py,pz,theta]
(paper §V: "constant-turn-rate with acceleration"). The dynamics are
nonlinear (the EKF linearizes via the Jacobian F_k each step); the
measurement map stays linear so the H_neg rewrite applies verbatim.

Beyond the paper, the IMM (interacting multiple model) estimator runs
K motion hypotheses per track as extra lanes of the same batched bank
(paper §IV-D generalized: model index stacks onto the filter index).
All IMM variants share one 9-dim state [px,py,pz,vx,vy,vz,ax,ay,az]
and the m=3 position-selector H, so every variant stays on the
selector-H matrix path of the ``katana_bank`` kernel:

  CV9 — constant velocity (acceleration states pinned to zero),
  CA9 — constant (Wiener-process) acceleration,
  CT9 — coordinated turn at a fixed rate omega about the z axis
        (exact linear discretization; one model per turn direction).

All matrices are built once at model-construction time, mirroring the
paper's constant folding (§IV-C): anything static (F, H, H_neg, their
transposes, Q, R, I) is a trace-time constant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True, eq=False)  # identity hash: usable as jit static arg
class FilterModel:
    """A (possibly nonlinear-dynamics) filter with linear measurements."""

    name: str
    n: int  # state dim
    m: int  # measurement dim
    is_linear: bool
    F: np.ndarray  # (n,n) — LKF transition (EKF: linearization point 0)
    H: np.ndarray  # (m,n) — measurement matrix (linear for both workloads)
    Q: np.ndarray  # (n,n) process noise
    R: np.ndarray  # (m,m) measurement noise
    x0: np.ndarray  # (n,) default initial state
    P0: np.ndarray  # (n,n) default initial covariance
    dt: float = 1.0 / 30.0
    # Nonlinear dynamics (EKF): f(x)->x', jac(x)->(n,n). None for LKF.
    f: Optional[Callable] = None
    F_jac: Optional[Callable] = None
    # Pure-numpy float64 mirrors for the oracle in ref.py.
    f_np: Optional[Callable] = None
    F_jac_np: Optional[Callable] = None

    def predict_mean(self, x):
        """Propagate the state mean (works on jnp arrays, batched or not)."""
        if self.is_linear:
            return x @ jnp.asarray(self.F, x.dtype).T
        return self.f(x)

    def jacobian(self, x):
        """(.., n, n) transition Jacobian at x."""
        if self.is_linear:
            F = jnp.asarray(self.F, x.dtype)
            return jnp.broadcast_to(F, x.shape[:-1] + (self.n, self.n))
        return self.F_jac(x)


def make_cv_lkf(dt: float = 1.0 / 30.0, q: float = 1e-2, r: float = 1e-1,
                p0: float = 1.0) -> FilterModel:
    """3-D constant-velocity LKF (the paper's §V n=6 workload:
    [p, v] state, position measurements, WNA process noise)."""
    n, m = 6, 3
    F = np.eye(n)
    F[:3, 3:] = dt * np.eye(3)
    H = np.zeros((m, n))
    H[:, :3] = np.eye(3)
    # Discretized white-noise-acceleration process covariance.
    G = np.zeros((n, 3))
    G[:3] = 0.5 * dt * dt * np.eye(3)
    G[3:] = dt * np.eye(3)
    Q = q * (G @ G.T) + 1e-9 * np.eye(n)
    R = r * np.eye(m)
    return FilterModel(
        name="lkf-cv6", n=n, m=m, is_linear=True, F=F, H=H, Q=Q, R=R,
        x0=np.zeros(n), P0=p0 * np.eye(n), dt=dt,
    )


def make_ctra_ekf(dt: float = 1.0 / 30.0, q: float = 1e-2, r: float = 1e-1,
                  p0: float = 1.0) -> FilterModel:
    """Constant-turn-rate + acceleration EKF (the paper's §V n=8
    workload).

    State: [px, py, pz, v, theta, omega, a, vz]; first-order discretized
    CTRA dynamics (no omega->0 singularity; pure mul/add + sin/cos, in
    the paper's spirit of keeping the graph on the matrix/vector units).
    """
    n, m = 8, 4

    def f(x):
        px, py, pz, v, th, om, a, vz = [x[..., i] for i in range(n)]
        c, s = jnp.cos(th), jnp.sin(th)
        return jnp.stack(
            [
                px + v * c * dt,
                py + v * s * dt,
                pz + vz * dt,
                v + a * dt,
                th + om * dt,
                om,
                a,
                vz,
            ],
            axis=-1,
        )

    def F_jac(x):
        v, th = x[..., 3], x[..., 4]
        c, s = jnp.cos(th), jnp.sin(th)
        batch = x.shape[:-1]
        F = jnp.broadcast_to(jnp.eye(n, dtype=x.dtype), batch + (n, n))
        upd = {
            (0, 3): c * dt, (0, 4): -v * s * dt,
            (1, 3): s * dt, (1, 4): v * c * dt,
            (2, 7): jnp.full(batch, dt, x.dtype),
            (3, 6): jnp.full(batch, dt, x.dtype),
            (4, 5): jnp.full(batch, dt, x.dtype),
        }
        for (i, j), val in upd.items():
            F = F.at[..., i, j].set(val)
        return F

    def f_np(x):
        x = np.asarray(x, np.float64)
        px, py, pz, v, th, om, a, vz = x
        c, s = np.cos(th), np.sin(th)
        return np.array(
            [px + v * c * dt, py + v * s * dt, pz + vz * dt, v + a * dt,
             th + om * dt, om, a, vz], np.float64)

    def F_jac_np(x):
        x = np.asarray(x, np.float64)
        v, th = x[3], x[4]
        c, s = np.cos(th), np.sin(th)
        F = np.eye(n)
        F[0, 3] = c * dt
        F[0, 4] = -v * s * dt
        F[1, 3] = s * dt
        F[1, 4] = v * c * dt
        F[2, 7] = dt
        F[3, 6] = dt
        F[4, 5] = dt
        return F

    H = np.zeros((m, n))
    H[0, 0] = H[1, 1] = H[2, 2] = 1.0  # position
    H[3, 4] = 1.0  # heading
    Q = q * np.eye(n)
    Q[5, 5] = Q[6, 6] = q * 0.1  # slowly-varying turn-rate / accel
    R = r * np.eye(m)
    x0 = np.zeros(n)
    x0[3] = 1.0  # unit speed so the Jacobian is non-degenerate at init
    # Linearization point for the "F" constant: Jacobian at x0.
    F0 = np.eye(n)
    F0[0, 3] = dt
    F0[1, 4] = dt
    F0[2, 7] = dt
    F0[3, 6] = dt
    F0[4, 5] = dt
    return FilterModel(
        name="ekf-ctra8", n=n, m=m, is_linear=False, F=F0, H=H, Q=Q, R=R,
        x0=x0, P0=p0 * np.eye(n), dt=dt, f=f, F_jac=F_jac,
        f_np=f_np, F_jac_np=F_jac_np,
    )


# ---------------------------------------------------------------------------
# IMM model set: K linear motion hypotheses on a shared 9-dim state.
# ---------------------------------------------------------------------------

IMM_STATE = ("px", "py", "pz", "vx", "vy", "vz", "ax", "ay", "az")


def _pos_selector_H(n: int) -> np.ndarray:
    """(3, n) position-selector measurement matrix (unit-vector rows, so
    the katana_bank kernel's selector-H fast path applies)."""
    H = np.zeros((3, n))
    H[:, :3] = np.eye(3)
    return H


def make_cv9_lkf(dt: float = 1.0 / 30.0, q: float = 1e-2, r: float = 1e-1,
                 p0: float = 1.0) -> FilterModel:
    """Constant-velocity model embedded in the shared 9-dim IMM state.

    The acceleration rows of F are zero — a CV-conditioned estimate
    forgets whatever acceleration the IMM mixing step blended in, which
    is exactly the "this target is NOT maneuvering" hypothesis.
    Same discretized white-noise-acceleration Q as ``make_cv_lkf``.
    """
    n, m = 9, 3
    F = np.zeros((n, n))
    F[:6, :6] = np.eye(6)
    F[:3, 3:6] = dt * np.eye(3)
    G = np.zeros((n, 3))
    G[:3] = 0.5 * dt * dt * np.eye(3)
    G[3:6] = dt * np.eye(3)
    Q = q * (G @ G.T) + 1e-9 * np.eye(n)
    return FilterModel(
        name="lkf-cv9", n=n, m=m, is_linear=True, F=F, H=_pos_selector_H(n),
        Q=Q, R=r * np.eye(m), x0=np.zeros(n), P0=p0 * np.eye(n), dt=dt,
    )


def make_ca9_lkf(dt: float = 1.0 / 30.0, q: float = 0.5, r: float = 1e-1,
                 p0: float = 1.0) -> FilterModel:
    """Constant (Wiener-process) acceleration model on the 9-dim state:
    p' = p + v dt + a dt^2/2; v' = v + a dt; a' = a, with white-noise
    *jerk* process covariance (q is the jerk PSD — large, because this
    is the maneuver hypothesis)."""
    n, m = 9, 3
    F = np.eye(n)
    F[:3, 3:6] = dt * np.eye(3)
    F[:3, 6:9] = 0.5 * dt * dt * np.eye(3)
    F[3:6, 6:9] = dt * np.eye(3)
    G = np.zeros((n, 3))
    G[:3] = (dt ** 3 / 6.0) * np.eye(3)
    G[3:6] = 0.5 * dt * dt * np.eye(3)
    G[6:9] = dt * np.eye(3)
    Q = q * (G @ G.T) + 1e-9 * np.eye(n)
    return FilterModel(
        name="lkf-ca9", n=n, m=m, is_linear=True, F=F, H=_pos_selector_H(n),
        Q=Q, R=r * np.eye(m), x0=np.zeros(n), P0=p0 * np.eye(n), dt=dt,
    )


def make_ct9_lkf(omega: float, dt: float = 1.0 / 30.0, q: float = 1e-2,
                 r: float = 1e-1, p0: float = 1.0) -> FilterModel:
    """Coordinated-turn model (fixed known rate ``omega`` rad/s about
    the z axis) on the 9-dim state. The exact linear discretization —
    position integrates the rotating velocity in closed form:

      p_xy' = p_xy + [[s/w, -(1-c)/w], [(1-c)/w, s/w]] v_xy
      v_xy' = [[c, -s], [s, c]] v_xy           (s=sin(w dt), c=cos(w dt))

    vz is constant-velocity; the acceleration rows are zero (the turn IS
    the maneuver — no extra accel state needed). One model per turn
    direction: build two CT9s with opposite omega signs."""
    if omega == 0.0:
        raise ValueError("omega must be nonzero; use make_cv9_lkf for w=0")
    n, m = 9, 3
    w = omega
    s, c = np.sin(w * dt), np.cos(w * dt)
    F = np.zeros((n, n))
    F[:3, :3] = np.eye(3)
    F[0, 3], F[0, 4] = s / w, -(1 - c) / w
    F[1, 3], F[1, 4] = (1 - c) / w, s / w
    F[2, 5] = dt
    F[3, 3], F[3, 4] = c, -s
    F[4, 3], F[4, 4] = s, c
    F[5, 5] = 1.0
    G = np.zeros((n, 3))
    G[:3] = 0.5 * dt * dt * np.eye(3)
    G[3:6] = dt * np.eye(3)
    Q = q * (G @ G.T) + 1e-9 * np.eye(n)
    return FilterModel(
        name=f"lkf-ct9({omega:+.2f})", n=n, m=m, is_linear=True, F=F,
        H=_pos_selector_H(n), Q=Q, R=r * np.eye(m), x0=np.zeros(n),
        P0=p0 * np.eye(n), dt=dt,
    )


@dataclass(frozen=True, eq=False)  # identity hash: usable as jit static arg
class IMMModel:
    """K filter hypotheses + the Markov mode chain (the IMM estimator).

    All member models must share (n, m) and the measurement matrix H —
    that is what lets the K variants run as stacked lanes of ONE padded
    ``katana_bank`` dispatch (the paper's §IV-D batching axis, reused
    for the model index).

    trans[i, j] = P(mode i -> mode j); rows sum to 1. mu0 is the spawn /
    initial mode distribution.
    """

    name: str
    models: Tuple[FilterModel, ...]
    trans: np.ndarray  # (K, K) row-stochastic mode transition matrix
    mu0: np.ndarray    # (K,) initial mode probabilities

    def __post_init__(self):
        K = len(self.models)
        assert K >= 1
        n, m = self.models[0].n, self.models[0].m
        for mdl in self.models:
            assert (mdl.n, mdl.m) == (n, m), "IMM models must share (n, m)"
            assert np.array_equal(mdl.H, self.models[0].H), \
                "IMM models must share H"
        assert self.trans.shape == (K, K)
        np.testing.assert_allclose(self.trans.sum(axis=1), 1.0, atol=1e-12)
        np.testing.assert_allclose(self.mu0.sum(), 1.0, atol=1e-12)

    @property
    def K(self) -> int:
        return len(self.models)

    @property
    def n(self) -> int:
        return self.models[0].n

    @property
    def m(self) -> int:
        return self.models[0].m

    @property
    def H(self) -> np.ndarray:
        return self.models[0].H

    @property
    def x0(self) -> np.ndarray:
        return self.models[0].x0

    @property
    def P0(self) -> np.ndarray:
        return self.models[0].P0

    @property
    def dt(self) -> float:
        return self.models[0].dt


def as_imm(model) -> IMMModel:
    """Wrap a single FilterModel as a degenerate K=1 IMM (the identity
    mode chain). IMM with K=1 reduces exactly to the plain bank —
    tested in tests/test_imm.py."""
    if isinstance(model, IMMModel):
        return model
    return IMMModel(name=f"imm1-{model.name}", models=(model,),
                    trans=np.ones((1, 1)), mu0=np.ones((1,)))


def make_imm(dt: float = 1.0 / 30.0, omega: float = 0.7,
             p_stay: float = 0.95, q_cv: float = 1e-2, q_ca: float = 0.5,
             r: float = 1e-1, p0: float = 1.0) -> IMMModel:
    """The default maneuvering-target IMM: CV9 + CA9 + CT9(±omega).

    ``p_stay`` is the per-frame probability of keeping the current mode;
    the remainder is spread uniformly over the other modes.
    """
    models = (
        make_cv9_lkf(dt=dt, q=q_cv, r=r, p0=p0),
        make_ca9_lkf(dt=dt, q=q_ca, r=r, p0=p0),
        make_ct9_lkf(omega, dt=dt, r=r, p0=p0),
        make_ct9_lkf(-omega, dt=dt, r=r, p0=p0),
    )
    K = len(models)
    trans = np.full((K, K), (1.0 - p_stay) / (K - 1))
    np.fill_diagonal(trans, p_stay)
    return IMMModel(name="imm-cv-ca-ct9", models=models, trans=trans,
                    mu0=np.full((K,), 1.0 / K))


def get_filter(kind: str, dt: float = 1.0 / 30.0) -> FilterModel:
    if kind == "lkf":
        return make_cv_lkf(dt=dt)
    if kind == "ekf":
        return make_ctra_ekf(dt=dt)
    if kind == "cv9":
        return make_cv9_lkf(dt=dt)
    if kind == "ca9":
        return make_ca9_lkf(dt=dt)
    raise KeyError(f"unknown filter kind {kind!r}")
