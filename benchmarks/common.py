"""Shared benchmark utilities: timing + HLO inspection + execution-mode
stamping (every BENCH row records how its code actually executed)."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.execmode import active_mode


def bench_meta() -> Dict:
    """Top-level BENCH_*.json metadata: the resolved execution mode
    (requested + actual), backend and jax version — so interpret-mode
    dispatch-count wins can never be conflated with compiled-mode
    wall-clock wins after the fact."""
    return active_mode().as_meta()


def row_mode(pallas: bool = True) -> Dict:
    """Per-row stamp: ``mode`` is "compiled" only for code that really
    compiled for this backend — XLA-native (einsum/lanes) formulations
    always, Pallas kernel dispatches only when the backend lowered them
    natively. ``lowering`` names the path ("xla" / "pallas" /
    "pallas-interpret"); ``backend`` the jax backend."""
    m = active_mode()
    return dict(mode=m.row_mode(pallas), lowering=m.lowering(pallas),
                backend=m.backend)


def row_tag(pallas: bool = True) -> str:
    """CSV-suffix form of ``row_mode`` for the harness's derived column."""
    r = row_mode(pallas)
    return f"mode={r['mode']};lowering={r['lowering']}"


def time_fn(fn: Callable, *args, iters: int = 50, warmup: int = 5,
            min_time_s: float = 0.0) -> float:
    """Mean wall seconds per call of a jitted fn (blocks on output)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    n = 0
    while True:
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        n += iters
        dt = time.perf_counter() - t0
        if dt >= min_time_s or n >= iters:
            return dt / n


def compiled_of(fn: Callable, *args):
    return jax.jit(fn).lower(*args).compile()


def hlo_op_counts(fn: Callable, *args, ops=("transpose", "reshape",
                                            "gather", "subtract", "dot",
                                            "add", "scatter")) -> Dict[str, int]:
    from repro.roofline.hlo import op_census

    return op_census(compiled_of(fn, *args).as_text(), ops)


def hlo_cost(fn: Callable, *args) -> Dict[str, float]:
    """XLA ``cost_analysis()`` of the compiled program: at least
    ``flops`` and ``bytes`` (the ``bytes accessed`` counter), 0.0 when
    the backend doesn't report a counter."""
    ca = compiled_of(fn, *args).cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4 returns [dict] per device
        ca = ca[0] if ca else {}
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)))


def hlo_flops(fn: Callable, *args) -> float:
    return hlo_cost(fn, *args)["flops"]
