"""Shared benchmark utilities: timing + HLO inspection."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp


def time_fn(fn: Callable, *args, iters: int = 50, warmup: int = 5,
            min_time_s: float = 0.0) -> float:
    """Mean wall seconds per call of a jitted fn (blocks on output)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    n = 0
    while True:
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        n += iters
        dt = time.perf_counter() - t0
        if dt >= min_time_s or n >= iters:
            return dt / n


def compiled_of(fn: Callable, *args):
    return jax.jit(fn).lower(*args).compile()


def hlo_op_counts(fn: Callable, *args, ops=("transpose", "reshape",
                                            "gather", "subtract", "dot",
                                            "add", "scatter")) -> Dict[str, int]:
    from repro.roofline.hlo import op_census

    return op_census(compiled_of(fn, *args).as_text(), ops)


def hlo_flops(fn: Callable, *args) -> float:
    ca = compiled_of(fn, *args).cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4 returns [dict] per device
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0))
