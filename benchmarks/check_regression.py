"""Bench-regression gate: committed RATIO baselines, not wall-clock.

Absolute throughput on a shared CI runner is noise — a different
machine, a noisy neighbor, a different core count all move it. What is
stable is the repo's own headline RATIOS: fused-scan vs per-frame loop,
fused frame vs einsum chain, fused IMM scan vs per-frame IMM driver.
A real regression (a kernel edit that quietly de-fuses a loop, a
wrapper that re-pays packing per frame) moves those ratios on ANY
machine, so that is what this gate pins.

    PYTHONPATH=src python -m benchmarks.check_regression            # gate
    PYTHONPATH=src python -m benchmarks.check_regression --update   # re-pin

Reads the BENCH_scan/imm/frame.json the bench run just wrote, extracts
the ratios keyed ``backend/mode`` + shape (an interpret-mode baseline
never judges a compiled run — the mode stamp keys the comparison, same
honesty rule as everywhere else in this PR), and compares against the
committed ``benchmarks/baseline_ratios.json``:

  * current < baseline x (1 - tol)  ->  FAIL (default tol 0.25: a >25%
    relative throughput regression on any pinned ratio).
  * a pinned key missing from the current run -> FAIL (a silently
    dropped bench row must not pass the gate).
  * keys the baseline doesn't pin are reported, not judged (new rows
    appear on --update).

The bench-smoke CI job runs this right after ``benchmarks.run --smoke``;
the committed baseline is generated from the same smoke shapes.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, Optional

ROOT = pathlib.Path(__file__).resolve().parents[1]
BASELINE_PATH = pathlib.Path(__file__).with_name("baseline_ratios.json")
DEFAULT_TOL = 0.25


def _load(root: pathlib.Path, name: str) -> Optional[Dict]:
    path = root / name
    if not path.exists():
        return None
    return json.loads(path.read_text())


def _prefix(doc: Dict) -> str:
    meta = doc.get("meta", {})
    return f"{meta.get('backend', '?')}/{meta.get('mode', '?')}"


def collect(root: Optional[pathlib.Path] = None) -> Dict[str, float]:
    """Ratio dict from the BENCH json files under ``root`` (repo root
    by default). Files that don't exist contribute nothing — the
    baseline then fails on the missing keys, which is the point."""
    root = root or ROOT
    out: Dict[str, float] = {}

    scan = _load(root, "BENCH_scan.json")
    if scan:
        p = _prefix(scan)
        for r in scan["rows"]:
            out[f"{p}/scan_fusion/{r['kind']}/N={r['N']}/fused_vs_loop"] = \
                r["speedup_fused_vs_loop"]

    imm = _load(root, "BENCH_imm.json")
    if imm:
        p = _prefix(imm)
        N = imm["N"]
        for key, field in (
                ("kernel_imm_vs_cv9", "ratio_kernel_imm_vs_cv9"),
                ("imm_scan_vs_per_frame", "speedup_imm_scan_vs_per_frame"),
                ("imm_scan_vs_ref", "ratio_imm_scan_vs_ref")):
            if field in imm:
                out[f"{p}/imm/N={N}/{key}"] = imm[field]

    frame = _load(root, "BENCH_frame.json")
    if frame:
        p = _prefix(frame)
        for r in frame["rows"]:
            out[f"{p}/frame/{r['kind']}/C={r['C']}/fused_vs_einsum"] = \
                r["speedup_fused_vs_einsum"]
        for r in frame.get("sharded", []):
            if not r.get("skipped"):
                out[f"{p}/frame/sharded/devices={r['devices']}"
                    f"/S={r['S']}/fused_vs_einsum"] = \
                    r["speedup_fused_vs_einsum"]

    serving = _load(root, "BENCH_serving.json")
    if serving:
        p = _prefix(serving)
        # only the DETERMINISTIC serving columns are pinnable: the
        # fake-clock drive makes served/recovered exact counts on any
        # machine, while frames_per_sec is wall-clock noise
        for r in serving["load_rows"]:
            out[f"{p}/serving/load={r['offered_x']}x"
                f"/tenants={r['tenants']}/served_fraction"] = \
                r["served_fraction"]
        fo = serving.get("failover")
        if fo:
            out[f"{p}/serving/failover/tenants={fo['tenants']}"
                f"/recovered"] = fo["recovered"]
    return out


def check(baseline: Dict[str, float], current: Dict[str, float],
          tol: float = DEFAULT_TOL):
    """-> (failures, notes): failures non-empty means the gate is red."""
    failures, notes = [], []
    for key, base in sorted(baseline.items()):
        cur = current.get(key)
        if cur is None:
            failures.append(f"MISSING  {key}: pinned at {base:.3f} but "
                            f"absent from this run — a dropped bench row "
                            f"(or stale baseline: --update after an "
                            f"intentional shape change)")
            continue
        floor = base * (1.0 - tol)
        if cur < floor:
            failures.append(
                f"REGRESSED {key}: {cur:.3f} < {floor:.3f} "
                f"(baseline {base:.3f}, tol {tol:.0%})")
        elif cur > base * (1.0 + tol):
            notes.append(f"improved {key}: {cur:.3f} vs baseline "
                         f"{base:.3f} — consider --update to re-pin")
        else:
            notes.append(f"ok       {key}: {cur:.3f} "
                         f"(baseline {base:.3f})")
    for key in sorted(set(current) - set(baseline)):
        notes.append(f"unpinned {key}: {current[key]:.3f} "
                     f"(--update to pin)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(BASELINE_PATH))
    ap.add_argument("--root", default=str(ROOT),
                    help="directory holding the BENCH_*.json files")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL)
    ap.add_argument("--update", action="store_true",
                    help="re-pin the baseline from the current run")
    args = ap.parse_args(argv)
    baseline_path = pathlib.Path(args.baseline)

    current = collect(pathlib.Path(args.root))
    if not current:
        print("no BENCH_*.json found — run `python -m benchmarks.run "
              "--only scan_fusion,imm,frame` first", file=sys.stderr)
        return 2

    if args.update:
        baseline_path.write_text(json.dumps(dict(
            note=("throughput-ratio floors for benchmarks/"
                  "check_regression.py; keys are backend/mode + shape, "
                  "regenerate with --update from the same shapes CI "
                  "runs (benchmarks.run --smoke)"),
            tol=args.tol, ratios=current), indent=2, sort_keys=True) + "\n")
        print(f"pinned {len(current)} ratios -> {baseline_path}")
        return 0

    if not baseline_path.exists():
        print(f"no baseline at {baseline_path} — run with --update to "
              f"create it", file=sys.stderr)
        return 2
    doc = json.loads(baseline_path.read_text())
    failures, notes = check(doc["ratios"], current,
                            args.tol if args.tol != DEFAULT_TOL
                            else doc.get("tol", DEFAULT_TOL))
    for line in notes:
        print(line)
    if failures:
        print(f"\n{len(failures)} bench regression(s):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"\nbench-regression gate green "
          f"({len(doc['ratios'])} pinned ratios)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
