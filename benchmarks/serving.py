"""Streaming front-end serving bench: sustained throughput vs offered
load, and recovery after a shard kill (serving/stream.py).

Three offered-load points — 0.5x, 1x and 2x of the front end's service
rate (one frame per tenant per pump) — each driven on a fake clock so
the BEHAVIOR (admission decisions, ladder tiers, shed counts) is fully
deterministic; only the wall-clock fps differs per machine. Reported
per row:

  * ``frames_per_sec``   — applied tenant-frames per wall second over
    the pump loop (compile excluded by explicit warmup);
  * ``served_fraction``  — applied frames that carried measurements
    (1.0 below saturation; the degradation ladder + anti-starvation
    floor set the 2x value);
  * ``shed_fraction``    — offered frames shed anywhere (ladder coast,
    drop-oldest, deadline expiry) / submitted;
  * ``reject_fraction``  — admission-rejected / submitted.

The ``failover`` section kills a shard mid-run and reports how many
driver cycles until every migrated tenant produced an update again,
plus the fraction of tenants that recovered (1.0 or the gate is red —
``tests/test_chaos.py`` separately proves the recovery is bitwise).

Results land in BENCH_serving.json; check_regression pins the
DETERMINISTIC columns (served fractions, failover recovery) — never
the machine-dependent fps.
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import bench_meta
from repro.core.filters import make_imm
from repro.core.tracker import TrackerConfig
from repro.serving.faults import ChaosDriver, FaultPlan
from repro.serving.stream import (ServiceTier, StreamConfig,
                                  StreamFrontEnd)

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_serving.json"


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _scene(seed: int):
    rng = np.random.default_rng(seed)
    pos = rng.normal(scale=8.0, size=(2, 3)).astype(np.float32)
    steps = rng.normal(scale=0.2, size=(512, 2, 3)).astype(np.float32)

    def scene(i):
        return pos + steps[: (i % 512) + 1].sum(0)

    return scene


def _front(tenants: int, tracker: TrackerConfig) -> StreamFrontEnd:
    clk = _Clock()
    lanes = max(tenants, 2)  # one shard must be able to absorb all
    front = StreamFrontEnd(
        make_imm(),
        StreamConfig(n_shards=2, lanes_per_shard=lanes, queue_depth=4,
                     checkpoint_every=8, heartbeat_timeout_s=1.0),
        tracker, ckpt_dir=tempfile.mkdtemp(prefix="bench_serving_"),
        clock=clk)
    for i in range(tenants):
        front.attach(f"tenant{i}")
    return front


def _warmup(front: StreamFrontEnd) -> None:
    """Compile both tier steps before any timer starts."""
    import jax.numpy as jnp
    L = front.cfg.lanes_per_shard
    M, m = front.tracker.max_meas, front.model.m
    zb = jnp.zeros((L, M, m), jnp.float32)
    vb = jnp.zeros((L, M), bool)
    for tier in (ServiceTier.FULL, ServiceTier.WIDE_GATE):
        front._step_for(tier)(front.shards[0].banks, zb, vb)


def _load_row(offered_x: float, tenants: int, cycles: int,
              tracker: TrackerConfig) -> Dict:
    front = _front(tenants, tracker)
    _warmup(front)
    scenes = {t: _scene(50 + i)
              for i, t in enumerate(sorted(front.tenants))}
    counts = {t: 0 for t in scenes}
    acc = 0.0
    applied = 0
    t0 = time.perf_counter()
    for _ in range(cycles):
        acc += offered_x
        while acc >= 1.0 - 1e-9:
            acc -= 1.0
            for t, scene in scenes.items():
                front.submit(t, scene(counts[t]))
                counts[t] += 1
        applied += len(front.pump())
        front.clock.advance(0.05)
    # drain the backlog so every accepted frame is accounted for
    for _ in range(4 * front.cfg.queue_depth):
        ups = front.pump()
        if not ups:
            break
        applied += len(ups)
        front.clock.advance(0.05)
    wall = time.perf_counter() - t0
    s = front.stats
    return dict(
        offered_x=offered_x,
        tenants=tenants,
        cycles=cycles,
        frames_per_sec=applied / wall if wall else 0.0,
        applied=applied,
        submitted=s.submitted,
        served_fraction=s.served / s.applied if s.applied else 0.0,
        shed_fraction=(s.shed + s.replaced_oldest + s.expired)
        / s.submitted if s.submitted else 0.0,
        reject_fraction=(s.rejected_overload + s.rejected_queue_full)
        / s.submitted if s.submitted else 0.0,
    )


def _failover_row(tenants: int, cycles: int,
                  tracker: TrackerConfig) -> Dict:
    front = _front(tenants, tracker)
    _warmup(front)
    kill_at = cycles // 3
    scenes = {t: _scene(90 + i)
              for i, t in enumerate(sorted(front.tenants))}
    drv = ChaosDriver(front, FaultPlan(kill_shards={kill_at: 0}),
                      scenes, front.clock.advance, dt_s=0.5)
    t0 = time.perf_counter()
    rep = drv.run(cycles)
    wall = time.perf_counter() - t0
    recovery = (max(rep.recovered_at.values()) - kill_at
                if rep.recovered_at else -1)
    return dict(
        tenants=tenants,
        cycles=cycles,
        kill_cycle=kill_at,
        exceptions=len(rep.exceptions),
        failovers=front.stats.failovers,
        parked=front.stats.parked,
        recovery_cycles=recovery,
        recovered=(front.stats.failovers
                   / max(1, front.stats.failovers + front.stats.parked)),
        wall_s=wall,
    )


def run(csv: List[str], tenants: int = 6, cycles: int = 60) -> None:
    tracker = TrackerConfig(capacity=8, max_meas=4)
    load_rows = [_load_row(x, tenants, cycles, tracker)
                 for x in (0.5, 1.0, 2.0)]
    failover = _failover_row(tenants, max(12, cycles // 2), tracker)
    for r in load_rows:
        csv.append(
            f"serving/load={r['offered_x']}x/tenants={tenants},"
            f"{1e6 / r['frames_per_sec']:.1f},"
            f"frames_per_sec={r['frames_per_sec']:.1f};"
            f"served_fraction={r['served_fraction']:.4f};"
            f"shed_fraction={r['shed_fraction']:.4f};"
            f"reject_fraction={r['reject_fraction']:.4f}")
    csv.append(
        f"serving/failover/tenants={failover['tenants']},0,"
        f"recovery_cycles={failover['recovery_cycles']};"
        f"recovered={failover['recovered']:.2f};"
        f"exceptions={failover['exceptions']}")
    BENCH_JSON.write_text(json.dumps(dict(
        meta=bench_meta(),
        tenants=tenants,
        cycles=cycles,
        load_rows=load_rows,
        failover=failover,
    ), indent=2) + "\n")
