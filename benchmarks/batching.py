"""§IV-D analysis: useful-FLOP fraction of the batching strategies,
plus the serving-scale follow-on — sharded multi-sensor IMM frames/sec.

The paper expands N filters into an (N·n)x(N·n) block-diagonal system
so the NPU's MAC array sees big GEMMs; on a TPU that expansion costs
O(N^2-N^3) redundant FLOPs. This bench measures compiled HLO FLOPs for
the paper-faithful expansion vs the TPU-native lane batching, against
the analytic useful-work floor.

The ``sharded_imm`` rows scale the OTHER batching axis: S independent
sensors, each a full IMM MOT frame (gating + assignment + lifecycle),
shard_mapped over a 1/2/4/8-device host-platform mesh
(``serving.engine.ShardedBankEngine``). Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get every
row; device counts that exceed the host (or don't divide S) are
skipped. Interpret-mode CPU numbers measure dispatch scaling, not TPU
silicon."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import hlo_flops
from repro.core.filters import get_filter
from repro.core.rewrites import build_stage, canonical_to_stage


def useful_flops(n: int, m: int) -> float:
    """Per-filter predict+update mul/adds (dense F, selector H)."""
    return 2.0 * (2 * n ** 3 + 2 * n * n * m + n * m * m + m ** 3 + n * m)


def run(csv: List[str], N: int = 200, imm_sensors: int = 8,
        imm_frames: int = 32) -> None:
    rng = np.random.default_rng(0)
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        floor = useful_flops(model.n, model.m) * N
        for stage in ("batched_blockdiag", "batched_lanes"):
            step, _ = build_stage(model, stage, N=N)
            x0 = np.tile(model.x0, (N, 1)).astype(np.float32)
            P0 = np.tile(model.P0, (N, 1, 1)).astype(np.float32)
            z0 = rng.normal(size=(N, model.m)).astype(np.float32)
            x, P, z = canonical_to_stage(stage, jnp.asarray(x0),
                                         jnp.asarray(P0), jnp.asarray(z0),
                                         model.n, model.m)
            fl = hlo_flops(step, x, P, z)
            csv.append(f"batching/{kind}/{stage}/N={N},{fl:.0f},"
                       f"useful_floor={floor:.0f};"
                       f"useful_fraction={min(1.0, floor / fl):.4f}")
    _run_sharded_imm(csv, imm_sensors, imm_frames)


def _run_sharded_imm(csv: List[str], S: int, T: int) -> None:
    """Sharded multi-sensor IMM serving throughput: S sensors, each a
    full K=4 IMM MOT frame, shard_mapped over 1/2/4/8 host devices.
    Times the live ``ShardedBankEngine.frame`` loop (compile excluded
    by the engine's warmup), reporting fleet frames/sec — one frame =
    all S sensors serviced."""
    from repro.compat import make_mesh
    from repro.core.filters import make_imm
    from repro.core.tracker import TrackerConfig
    from repro.serving.engine import ShardedBankEngine

    imm = make_imm()
    cfg = TrackerConfig(capacity=16, max_meas=8)
    n_dev = len(jax.devices())
    rng = np.random.default_rng(3)
    pos = rng.normal(size=(S, 2, 3)) * 3
    z = np.zeros((T, S, cfg.max_meas, imm.m), np.float32)
    v = np.zeros((T, S, cfg.max_meas), bool)
    for t in range(T):
        pos = pos + 0.05
        z[t, :, :2] = pos + rng.normal(size=pos.shape) * 0.05
        v[t, :, :2] = True
    base_fps = None
    for d in (1, 2, 4, 8):
        if d > n_dev or S % d:
            csv.append(f"batching/sharded_imm/devices={d}/S={S},0,"
                       f"skipped=need {d} devices dividing S={S}")
            continue
        eng = ShardedBankEngine(imm, S, cfg, mesh=make_mesh((d,), ("data",)))
        for t in range(T):
            eng.frame(z[t], v[t])
        fps = eng.stats.fps
        base_fps = base_fps or fps
        csv.append(f"batching/sharded_imm/devices={d}/S={S},"
                   f"{1e6 / fps:.1f},frames_per_sec={fps:.1f};"
                   f"scaling_vs_1dev={fps / base_fps:.2f}")
