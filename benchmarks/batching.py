"""§IV-D analysis: useful-FLOP fraction of the batching strategies.

The paper expands N filters into an (N·n)x(N·n) block-diagonal system
so the NPU's MAC array sees big GEMMs; on a TPU that expansion costs
O(N^2-N^3) redundant FLOPs. This bench measures compiled HLO FLOPs for
the paper-faithful expansion vs the TPU-native lane batching, against
the analytic useful-work floor."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import hlo_flops
from repro.core.filters import get_filter
from repro.core.rewrites import build_stage, canonical_to_stage


def useful_flops(n: int, m: int) -> float:
    """Per-filter predict+update mul/adds (dense F, selector H)."""
    return 2.0 * (2 * n ** 3 + 2 * n * n * m + n * m * m + m ** 3 + n * m)


def run(csv: List[str], N: int = 200) -> None:
    rng = np.random.default_rng(0)
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        floor = useful_flops(model.n, model.m) * N
        for stage in ("batched_blockdiag", "batched_lanes"):
            step, _ = build_stage(model, stage, N=N)
            x0 = np.tile(model.x0, (N, 1)).astype(np.float32)
            P0 = np.tile(model.P0, (N, 1, 1)).astype(np.float32)
            z0 = rng.normal(size=(N, model.m)).astype(np.float32)
            x, P, z = canonical_to_stage(stage, jnp.asarray(x0),
                                         jnp.asarray(P0), jnp.asarray(z0),
                                         model.n, model.m)
            fl = hlo_flops(step, x, P, z)
            csv.append(f"batching/{kind}/{stage}/N={N},{fl:.0f},"
                       f"useful_floor={floor:.0f};"
                       f"useful_fraction={min(1.0, floor / fl):.4f}")
