"""Table I analogue: latency / throughput of every (filter x stage x N)
variant on this container's CPU-XLA backend.

The paper's absolute numbers are NPU-silicon-specific; what reproduces
is the SHAPE of the table: per-stage single-filter latencies in the
same band (rewrites are latency-neutral at N=1), and the batched regime
where the restructured graph pays off. The beyond-paper rows
(batched_lanes, katana_bank-ref semantics) show the N^2 FLOP collapse
vs the paper's block-diagonal expansion.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.filters import get_filter
from repro.core.rewrites import build_stage, canonical_to_stage

STAGES_1 = ("baseline", "opt1", "opt2")
STAGES_N = ("batched_blockdiag", "batched_lanes")
N_BATCH = 200


def bench_stage(model, stage: str, N: int, iters: int, rng) -> float:
    step, _ = build_stage(model, stage, N=N)
    x0 = np.tile(model.x0, (N, 1)).astype(np.float32)
    P0 = np.tile(model.P0, (N, 1, 1)).astype(np.float32)
    z0 = rng.normal(size=(N, model.m)).astype(np.float32)
    x, P, z = canonical_to_stage(stage, jnp.asarray(x0), jnp.asarray(P0),
                                 jnp.asarray(z0), model.n, model.m)
    jitted = jax.jit(step)
    return time_fn(jitted, x, P, z, iters=iters, warmup=2)


def run(csv: List[str]) -> None:
    rng = np.random.default_rng(0)
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        for stage in STAGES_1:
            s = bench_stage(model, stage, 1, iters=200, rng=rng)
            csv.append(f"table1/{kind}/{stage}/N=1,{s * 1e6:.2f},"
                       f"fps={1.0 / s:.1f}")
        for stage in STAGES_N:
            iters = 2 if stage == "batched_blockdiag" else 50
            s = bench_stage(model, stage, N_BATCH, iters=iters, rng=rng)
            csv.append(f"table1/{kind}/{stage}/N={N_BATCH},{s * 1e6:.2f},"
                       f"fps={1.0 / s:.1f}")
