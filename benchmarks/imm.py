"""IMM bench: accuracy and throughput of the multi-model bank.

Two questions, answered on the maneuvering-target scene
(``repro.data.trajectories.maneuvering_batch`` — CV/CT/CA segment
switching, the model-mismatch regime of the KalmanNet comparative
study, arXiv:2411.16930):

  1. Accuracy: position RMSE of the IMM bank vs the single-model CV
     filters (both the paper's cv-6 LKF and the 9-state CV embedded in
     the IMM state space). A lone CV filter mis-models every turn and
     acceleration segment; the IMM's CT/CA hypotheses pick them up.
  2. Throughput: steps/sec at equal track count.
       * ``kernel`` rows time the SoA-resident dispatch
         (``katana_bank_step`` vs ``katana_bank_imm_step``) — the
         serving-resident layout where only kernel math is on the
         clock. This is the apples-to-apples cost of running K=4
         hypotheses as stacked lanes of one padded dispatch: both
         configurations occupy the same 256 padded lanes, so the ratio
         is pure emitted-op count.
       * ``sequence`` rows time the end-to-end drivers:
         ``katana_bank_sequence``'s one-dispatch fused scan,
         ``imm_bank_sequence``'s per-frame IMM scan (mix -> kernel ->
         posterior, one dispatch + packing PER FRAME), and
         ``katana_imm_sequence``'s fused IMM scan (``imm_scan`` stage:
         mixing and mode posterior inside the kernel's time loop, ONE
         dispatch per sequence). ``speedup_imm_scan_vs_per_frame`` is
         the headline: the dispatch-granularity win the fusion buys.
       * ``tracker`` rows time the full jitted MOT frame step — gating
         + greedy assignment + lifecycle included —
         ``frame_step`` (single-model cv9) vs ``imm_frame_step`` (K=4):
         the end-to-end serving cost of multi-model estimation.

Results land in BENCH_imm.json, every row stamped with how it actually
executed (mode / lowering / backend): on a CPU container the Pallas
rows run interpreted — those numbers overweight per-op dispatch
overhead relative to TPU silicon, and the kernel-level ratio is the
portable signal — while ``imm_ref_sequence`` (the einsum reference
recursion under one jitted lax.scan) is real compiled XLA everywhere,
the honest compiled-mode IMM baseline
(``ratio_imm_scan_vs_ref``).
"""
from __future__ import annotations

import json
import pathlib
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_meta, row_mode, row_tag, time_fn
from repro.core.filters import get_filter, make_cv9_lkf, make_imm
from repro.core.rewrites import imm_combine, imm_mix, imm_mode_posterior
from repro.core.tracker import (TrackerConfig, make_jitted_imm_tracker,
                                make_jitted_tracker)
from repro.data.trajectories import maneuvering_batch
from repro.execmode import active_mode
from repro.kernels.katana_bank.kernel import (katana_bank_imm_step,
                                              katana_bank_step)
from repro.kernels.katana_bank.ops import (_imm_lane_table, _pad_to,
                                           imm_bank_sequence,
                                           katana_bank_sequence,
                                           katana_imm_sequence)
from repro.kernels.katana_bank.ref import katana_imm_ref

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_imm.json"

WARMUP_FRAMES = 20  # RMSE excludes the initial convergence transient


def _pos_rmse(est: np.ndarray, truth: np.ndarray, warm: int) -> float:
    return float(np.sqrt(np.mean(
        (est[warm:, :, :3] - truth[warm:, :, :3]) ** 2)))


def _soa_state(model, N: int, L: int, seed: int):
    rng = np.random.default_rng(seed)
    n, m = model.n, model.m
    x = _pad_to(jnp.asarray(rng.normal(size=(n, N)) * 0.5, jnp.float32), L)
    P = _pad_to(jnp.asarray(
        np.tile(np.asarray(model.P0, np.float32)[:, :, None], (1, 1, N)),
        jnp.float32), L)
    z = _pad_to(jnp.asarray(rng.normal(size=(m, N)) * 0.5, jnp.float32), L)
    return x, P, z


def run(csv: List[str], N: int = 64, T: int = 96) -> None:
    cv6 = get_filter("lkf")
    cv9 = make_cv9_lkf()
    imm = make_imm()
    K = imm.K
    warm = min(WARMUP_FRAMES, T // 4)  # smoke shapes have no transient room

    truth, zs = maneuvering_batch(T, N, seed=1)
    zsf = jnp.asarray(zs, jnp.float32)

    def seq_inputs(model):
        return (jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32),
                jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32))

    # ---- accuracy: RMSE vs single-model CV on the maneuvering scene ----
    x6, P6 = seq_inputs(cv6)
    x9, P9 = seq_inputs(cv9)
    est_cv6 = np.asarray(katana_bank_sequence(cv6, zsf, x6, P6))
    est_cv9 = np.asarray(katana_bank_sequence(cv9, zsf, x9, P9))
    est_imm = np.asarray(imm_bank_sequence(imm, zsf, x9, P9))
    est_imm_scan = np.asarray(katana_imm_sequence(imm, zsf, x9, P9))
    np.testing.assert_allclose(est_imm_scan, est_imm, atol=5e-4, rtol=5e-4)
    rmse = dict(
        measurements=float(np.sqrt(np.mean(
            (zs[warm:] - truth[warm:, :, :3]) ** 2))),
        cv6=_pos_rmse(est_cv6, truth, warm),
        cv9=_pos_rmse(est_cv9, truth, warm),
        imm=_pos_rmse(est_imm, truth, warm),
        imm_scan=_pos_rmse(est_imm_scan, truth, warm),
    )
    for k, v in rmse.items():
        csv.append(f"imm/rmse/{k}/N={N},0,rmse={v:.4f}")

    # ---- throughput: SoA kernel dispatch at equal track count ----
    interp = active_mode().interpret  # kernel.py is mode-unaware; pass it
    L = -(-K * N // 256) * 256  # both sides padded to the same lane tile
    xs, Ps, zsoa = _soa_state(cv9, N, L, seed=2)
    x6s, P6s, z6s = _soa_state(cv6, N, L, seed=2)
    tab = jnp.asarray(_imm_lane_table(imm, N, L))
    kernel_fns = {
        "cv6_kernel": (lambda: katana_bank_step(cv6, x6s, P6s, z6s,
                                                interpret=interp)),
        "cv9_kernel": (lambda: katana_bank_step(cv9, xs, Ps, zsoa,
                                                interpret=interp)),
        "imm_kernel": (lambda: katana_bank_imm_step(imm, xs, Ps, zsoa, tab,
                                                    interpret=interp)),
    }
    timings = {}
    for name, fn in kernel_fns.items():
        # best-of-rounds: the min is robust to the container's noisy
        # scheduler, which otherwise swamps the ~200us dispatches
        sec = min(time_fn(fn, iters=20, warmup=3) for _ in range(5))
        timings[name] = dict(us_per_frame=sec * 1e6, steps_per_sec=1.0 / sec,
                             **row_mode(pallas=True))
        csv.append(f"imm/{name}/N={N},{sec * 1e6:.1f},"
                   f"steps_per_sec={1.0 / sec:.1f};{row_tag(True)}")

    # ---- throughput: end-to-end sequence drivers ----
    # the XLA-native reference recursion (ref-oracle models + einsum
    # mixing under one jitted lax.scan): REAL compiled code on every
    # backend, so on CPU it is the only honest compiled-mode IMM
    # sequence row next to the interpret-stamped Pallas rows
    Pi = jnp.asarray(imm.trans, jnp.float32)
    mu0 = jnp.broadcast_to(jnp.asarray(imm.mu0, jnp.float32), (N, K))
    xK0 = jnp.broadcast_to(x9, (K,) + x9.shape)
    PK0 = jnp.broadcast_to(P9, (K,) + P9.shape)

    @jax.jit
    def imm_ref_scan(zs=zsf):
        def body(carry, z_t):
            x, P, mu = carry
            x_mix, P_mix, cbar = imm_mix(x, P, mu, Pi)
            x_new, P_new, loglik = katana_imm_ref(imm, x_mix, P_mix, z_t)
            mu_new = imm_mode_posterior(cbar, loglik)
            x_c, _ = imm_combine(x_new, P_new, mu_new)
            return (x_new, P_new, mu_new), x_c
        _, x_cs = jax.lax.scan(body, (xK0, PK0, mu0), zs)
        return x_cs

    # equivalence gate before timing: the compiled reference must agree
    # with the fused kernel it is benchmarked against
    np.testing.assert_allclose(np.asarray(imm_ref_scan()), est_imm_scan,
                               atol=2e-3, rtol=2e-3)

    seq_fns = {
        "cv9_sequence": (lambda: katana_bank_sequence(cv9, zsf, x9, P9),
                         True),
        "imm_sequence": (lambda: imm_bank_sequence(imm, zsf, x9, P9), True),
        "imm_scan_sequence": (lambda: katana_imm_sequence(imm, zsf, x9, P9),
                              True),
        "imm_ref_sequence": (imm_ref_scan, False),
    }
    for name, (fn, pallas) in seq_fns.items():
        # best-of-rounds: min is robust to the container's noisy
        # scheduler (same protocol as the kernel rows)
        sec = min(time_fn(fn, iters=3, warmup=1) for _ in range(5))
        timings[name] = dict(us_per_frame=sec / T * 1e6,
                             steps_per_sec=T / sec, **row_mode(pallas))
        csv.append(f"imm/{name}/N={N},{sec / T * 1e6:.1f},"
                   f"steps_per_sec={T / sec:.1f};{row_tag(pallas)}")

    # ---- throughput: full tracker frame (gating + assignment included) ----
    cfg = TrackerConfig(capacity=max(2 * N, 16), max_meas=max(N, 8))
    z_frame = np.zeros((cfg.max_meas, 3), np.float32)
    z_frame[:N] = zs[T // 2]
    v_frame = np.zeros((cfg.max_meas,), bool)
    v_frame[:N] = True
    zj, vj = jnp.asarray(z_frame), jnp.asarray(v_frame)
    tracker_fns = {}
    for name, (init, step) in (
            ("cv9_tracker", make_jitted_tracker(cv9, cfg)),
            ("imm_tracker", make_jitted_imm_tracker(imm, cfg))):
        bank = init()
        for t in range(3):  # seed + confirm tracks before timing
            bank = step(bank, zj, vj).bank
        tracker_fns[name] = (lambda step=step, bank=bank:
                             step(bank, zj, vj).bank.x)
    for name, fn in tracker_fns.items():
        sec = min(time_fn(fn, iters=10, warmup=2) for _ in range(3))
        # default TrackerConfig routes through the fused Pallas frame
        timings[name] = dict(us_per_frame=sec * 1e6, steps_per_sec=1.0 / sec,
                             **row_mode(pallas=True))
        csv.append(f"imm/{name}/N={N},{sec * 1e6:.1f},"
                   f"steps_per_sec={1.0 / sec:.1f};{row_tag(True)}")

    ratio_kernel = (timings["imm_kernel"]["steps_per_sec"]
                    / timings["cv9_kernel"]["steps_per_sec"])
    ratio_seq = (timings["imm_sequence"]["steps_per_sec"]
                 / timings["cv9_sequence"]["steps_per_sec"])
    ratio_scan = (timings["imm_scan_sequence"]["steps_per_sec"]
                  / timings["cv9_sequence"]["steps_per_sec"])
    speedup_fused = (timings["imm_scan_sequence"]["steps_per_sec"]
                     / timings["imm_sequence"]["steps_per_sec"])
    ratio_scan_vs_ref = (timings["imm_scan_sequence"]["steps_per_sec"]
                         / timings["imm_ref_sequence"]["steps_per_sec"])
    ratio_tracker = (timings["imm_tracker"]["steps_per_sec"]
                     / timings["cv9_tracker"]["steps_per_sec"])
    csv.append(f"imm/ratio_kernel_imm_vs_cv9/N={N},0,x{ratio_kernel:.2f}")
    csv.append(f"imm/ratio_sequence_imm_vs_cv9/N={N},0,x{ratio_seq:.2f}")
    csv.append(f"imm/ratio_imm_scan_vs_cv9/N={N},0,x{ratio_scan:.2f}")
    csv.append(f"imm/speedup_imm_scan_vs_per_frame/N={N},0,"
               f"x{speedup_fused:.2f}")
    csv.append(f"imm/ratio_imm_scan_vs_ref/N={N},0,x{ratio_scan_vs_ref:.2f}")
    csv.append(f"imm/ratio_tracker_imm_vs_cv9/N={N},0,x{ratio_tracker:.2f}")

    BENCH_JSON.write_text(json.dumps(dict(
        bench="imm", meta=bench_meta(), N=N, T=T, K=K,
        scene=dict(generator="maneuvering_batch", seed=1),
        rmse=rmse,
        rmse_improvement_vs_cv6=rmse["cv6"] / rmse["imm"],
        timings=timings,
        ratio_kernel_imm_vs_cv9=ratio_kernel,
        ratio_sequence_imm_vs_cv9=ratio_seq,
        ratio_imm_scan_vs_cv9=ratio_scan,
        speedup_imm_scan_vs_per_frame=speedup_fused,
        ratio_imm_scan_vs_ref=ratio_scan_vs_ref,
        ratio_tracker_imm_vs_cv9=ratio_tracker,
        notes=("kernel rows: SoA-resident dispatch, equal padded lane "
               "count — the portable cost of K hypotheses as stacked "
               "lanes. sequence rows: imm_sequence pays per-frame "
               "dispatch + packing (mixing between dispatches); "
               "imm_scan_sequence fuses mixing + mode posterior into "
               "the scan kernel's time loop — one dispatch per "
               "sequence (speedup_imm_scan_vs_per_frame); "
               "imm_ref_sequence is the XLA-native einsum recursion "
               "under lax.scan — compiled code on every backend, the "
               "row to read when Pallas rows are interpret-stamped. "
               "tracker rows: the full jitted MOT frame step incl. "
               "gating + greedy assignment."),
    ), indent=2) + "\n")
