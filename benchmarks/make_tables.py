"""Inject the roofline tables into EXPERIMENTS.md placeholders.

  PYTHONPATH=src python -m benchmarks.make_tables
"""
from __future__ import annotations

import re
from pathlib import Path

from benchmarks.roofline import table

ROOT = Path(__file__).resolve().parents[1]


def main() -> None:
    md = (ROOT / "EXPERIMENTS.md").read_text()
    for mesh in ("single", "multi"):
        marker = f"<!-- ROOFLINE_TABLE_{mesh.upper()} -->"
        block = f"{marker}\n\n{table(mesh)}\n"
        pat = re.compile(re.escape(marker) + r"(\n\n\|.*?\n)?(?=\n)",
                         re.DOTALL)
        if marker in md:
            # replace marker (+ any previously injected table)
            start = md.index(marker)
            end = start + len(marker)
            # consume a previously injected table if present
            rest = md[end:]
            m = re.match(r"\n\n(\|[^\n]*\n)+", rest)
            if m:
                end += m.end()
            md = md[:start] + block + md[end:]
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("tables injected")


if __name__ == "__main__":
    main()
