"""Tile autotuner CLI: measure lane_tile/time_chunk candidates per
(kernel, bank size) under the ACTIVE execution mode and persist the
winners to the checked-in table the ops wrappers consult
(src/repro/kernels/katana_bank/tuned.json — see autotune.py there for
the format and lookup rules).

    PYTHONPATH=src python -m benchmarks.autotune [--Ns 64,256] [--T 16]
        [--out PATH] [--dry-run]

Entries are keyed ``backend/mode`` with the RESOLVED mode, so a table
tuned on this CPU container only ever drives cpu/interpret runs; a TPU
machine re-running the CLI adds tpu/compiled rows next to them instead
of overwriting. Candidates that fail to build (tile constraints) are
skipped, not fatal — the table is advisory and the static defaults in
autotune.STATIC_DEFAULTS always remain the fallback.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.filters import get_filter, make_imm
from repro.execmode import active_mode
from repro.kernels.katana_bank import autotune as table_lib
from repro.kernels.katana_bank.ops import (katana_bank,
                                           katana_bank_sequence,
                                           katana_imm_sequence)

LANE_TILES = (64, 128, 256, 512)
TIME_CHUNKS = (256, 1024, 4096)
# IMM lane tiles are per-model-slot (K models resident per program);
# 0 keeps ops' LANE_TILE//K power-of-two heuristic in the race
IMM_LANE_TILES = (0, 32, 64, 128)
IMM_TIME_CHUNKS = (16, 64, 256)


def _best(candidates, measure) -> Optional[Dict]:
    """Race the candidate configs; None when every one failed."""
    best = None
    for cfg in candidates:
        try:
            us = measure(**cfg)
        except Exception as e:  # noqa: BLE001 - tile-constraint rejects
            print(f"    skip {cfg}: {type(e).__name__}: {e}")
            continue
        print(f"    {cfg} -> {us:.1f} us/frame")
        if best is None or us < best["us_per_frame"]:
            best = dict(cfg, us_per_frame=round(us, 2))
    return best


def tune(Ns=(64, 256), T: int = 16, rounds: int = 2,
         iters: int = 2) -> Dict:
    """Measure all kernels at all bank sizes; return the entries dict
    for ``write_table`` (only the active backend/mode key)."""
    mode = active_mode()
    key = f"{mode.backend}/{mode.mode}"
    print(f"autotuning for {key} (requested={mode.requested}, "
          f"fallback={mode.fallback})")
    lkf = get_filter("lkf")
    imm = make_imm()
    rng = np.random.default_rng(3)
    entries: Dict[str, Dict[str, List[Dict]]] = {}

    def record(kernel: str, N: int, best: Optional[Dict]) -> None:
        if best is not None:
            entries.setdefault(kernel, {}).setdefault(key, []).append(
                dict(N=N, **best))

    for N in Ns:
        print(f"  N={N}")
        zs = jnp.asarray(rng.normal(size=(T, N, lkf.m)) * 0.5, jnp.float32)
        x0 = jnp.asarray(np.tile(lkf.x0, (N, 1)), jnp.float32)
        P0 = jnp.asarray(np.tile(lkf.P0, (N, 1, 1)), jnp.float32)

        def m_bank(lane_tile):
            fn = lambda: katana_bank(lkf, x0, P0, zs[0], lane_tile=lane_tile)
            return min(time_fn(fn, iters=iters, warmup=1)
                       for _ in range(rounds)) * 1e6

        record("katana_bank", N,
               _best([dict(lane_tile=t) for t in LANE_TILES], m_bank))

        def m_seq(lane_tile, time_chunk):
            fn = lambda: katana_bank_sequence(
                lkf, zs, x0, P0, lane_tile=lane_tile, time_chunk=time_chunk)
            return min(time_fn(fn, iters=iters, warmup=1)
                       for _ in range(rounds)) / T * 1e6

        record("katana_bank_sequence", N, _best(
            [dict(lane_tile=t, time_chunk=c)
             for t in LANE_TILES for c in TIME_CHUNKS if c >= T], m_seq))

        zs9 = jnp.asarray(rng.normal(size=(T, N, imm.m)) * 0.5, jnp.float32)
        x9 = jnp.asarray(np.tile(imm.models[0].x0, (N, 1)), jnp.float32)
        P9 = jnp.asarray(np.tile(imm.models[0].P0, (N, 1, 1)), jnp.float32)

        def m_imm(lane_tile, time_chunk):
            fn = lambda: katana_imm_sequence(
                imm, zs9, x9, P9, lane_tile=lane_tile, time_chunk=time_chunk)
            return min(time_fn(fn, iters=iters, warmup=1)
                       for _ in range(rounds)) / T * 1e6

        record("katana_imm_sequence", N, _best(
            [dict(lane_tile=t, time_chunk=c)
             for t in IMM_LANE_TILES for c in IMM_TIME_CHUNKS], m_imm))

    return entries


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--Ns", default="64,256")
    ap.add_argument("--T", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--out", default=None,
                    help="table path (default: the checked-in tuned.json)")
    ap.add_argument("--dry-run", action="store_true",
                    help="measure + print, don't write the table")
    args = ap.parse_args(argv)
    Ns = tuple(int(n) for n in args.Ns.split(","))

    new = tune(Ns=Ns, T=args.T, rounds=args.rounds)
    # merge over the existing table: other kernels and other
    # backend/mode keys (e.g. a TPU's rows) survive a CPU re-tune
    path = table_lib.TUNED_PATH if args.out is None else \
        pathlib.Path(args.out)
    merged = {k: dict(v) for k, v in
              table_lib._load_table(str(path)).items()}
    for kernel, by_key in new.items():
        merged.setdefault(kernel, {}).update(by_key)
    print(json.dumps(merged, indent=2, sort_keys=True))
    if args.dry_run:
        return
    table_lib.write_table(merged, path)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
