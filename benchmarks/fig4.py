"""Fig. 3/4 analogue: optimized-HLO op census per rewrite stage.

The paper shows Netron graphs / Perfetto traces where each stage
removes DSP-bound op classes. Our substrate's equivalent evidence:
counts of subtract / transpose / reshape / gather ops in the compiled
XLA graph, per stage. Opt-1 must eliminate subtracts from the steady
state; Opt-2 must eliminate the system-matrix transposes and exporter
reshapes (the remaining data reshapes/layout ops are XLA-internal).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import hlo_op_counts
from repro.core.filters import get_filter
from repro.core.rewrites import build_stage, canonical_to_stage

OPS = ("subtract", "transpose", "reshape", "gather", "dot", "add")


def census(model, stage: str, N: int = 1):
    step, _ = build_stage(model, stage, N=N)
    rng = np.random.default_rng(0)
    x0 = np.tile(model.x0, (N, 1)).astype(np.float32)
    P0 = np.tile(model.P0, (N, 1, 1)).astype(np.float32)
    z0 = rng.normal(size=(N, model.m)).astype(np.float32)
    x, P, z = canonical_to_stage(stage, jnp.asarray(x0), jnp.asarray(P0),
                                 jnp.asarray(z0), model.n, model.m)
    return hlo_op_counts(step, x, P, z, ops=OPS)


def run(csv: List[str]) -> None:
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        for stage, N in (("baseline", 1), ("opt1", 1), ("opt2", 1),
                         ("batched_lanes", 200)):
            c = census(model, stage, N)
            csv.append(
                f"fig4/{kind}/{stage},0,"
                + ";".join(f"{k}={c[k]}" for k in OPS))
