"""Roofline table: reads results/dryrun/ JSONs (written by
repro.launch.dryrun) and prints the three-term analysis per cell."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: str):
    cells = []
    root = RESULTS / mesh
    if not root.exists():
        return cells
    for f in sorted(root.glob("*/*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def run(csv: List[str], mesh: str = "single") -> None:
    for rec in load_cells(mesh):
        tag = f"roofline/{rec['mesh']}/{rec['arch']}/{rec['shape']}"
        if not rec.get("supported", True):
            csv.append(f"{tag},0,skip={rec['skip_reason']}")
            continue
        r = rec.get("roofline")
        if not r:
            csv.append(f"{tag},0,no-probe")
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        csv.append(
            f"{tag},{bound * 1e6:.0f},"
            f"tc={r['t_compute_s']:.4f};tm={r['t_memory_s']:.4f};"
            f"tcoll={r['t_collective_s']:.4f};dom={r['dominant']};"
            f"useful={r['useful_fraction']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f}")


def table(mesh: str = "single") -> str:
    """Markdown table for EXPERIMENTS.md."""
    rows = [
        "| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant "
        "| useful | roofline frac | fits 16G (tpu-est) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(mesh):
        if not rec.get("supported", True):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped: {rec['skip_reason']} | — | — | — |")
            continue
        r = rec.get("roofline", {})
        f = rec.get("full", {})
        if not r:
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['t_compute_s']:.4g} "
            f"| {r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} "
            f"| {r['dominant']} | {r['useful_fraction']:.3f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {f.get('fits_16g_tpu_est', '—')} |")
    return "\n".join(rows)
