"""Katana-kernel roofline: achieved FLOPs/bytes of the COMPILED
programs vs the three-term roofline model.

For each stage of the serving path — the fused multi-frame scan
(``katana_bank_sequence``), its XLA-native twin (the batched_lanes
einsum stage under ``lax.scan``), the fused IMM scan
(``katana_imm_sequence``) and the live frame (``tracker.frame_step``,
fused and einsum routes) — this bench:

  * compiles the program (``jit(...).lower(...).compile()``) and reads
    XLA's ``cost_analysis()`` FLOPs + bytes-accessed, plus an
    optimized-HLO op census (``repro.roofline.hlo.op_census``);
  * computes the ANALYTIC useful-work floor (the paper's §IV-D
    mul/add count per filter step, ``benchmarks.batching.useful_flops``,
    extended to IMM mixing) and the minimal HBM crossings (measurement
    stream in, estimates out, bank once per chunk);
  * evaluates the three-term roofline on the backend's ``Machine``
    (``repro.roofline.analysis``) and times the real call —
    ``roofline_fraction`` = analytic bound / measured wall-clock is the
    honest "how far from the roofline" number, ``useful_fraction`` =
    useful / compiled FLOPs the arithmetic-overhead number (the axis
    Cerati et al. and Tithi et al. show small-matrix tracking lives or
    dies on).

Rows land in BENCH_roofline.json with the execution mode stamped per
row — a Pallas program that ran through the interpreter is labelled
``mode=interpret`` and its cost_analysis reflects the EMULATED op
stream, which is exactly the conflation this file exists to make
visible (the XLA rows are compiled code on every backend, CPU
included). Variants a backend can't run emit explicit ``skip=`` rows
(batching.py's convention), never silence.

The legacy dry-run table reader (``load_cells`` / ``table``, consumed
by benchmarks/make_tables.py) is kept below; its ``results/dryrun/``
artifacts don't exist in this repo, and ``run`` now says so with an
explicit skip row instead of silently emitting nothing.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.batching import useful_flops
from benchmarks.common import bench_meta, compiled_of, row_mode, time_fn
from repro.core.filters import get_filter, make_imm
from repro.core.rewrites import build_stage
from repro.execmode import active_mode
from repro.kernels.katana_bank.ops import (katana_bank_sequence,
                                           katana_imm_sequence)
from repro.roofline.analysis import machine_for_backend, terms_on
from repro.roofline.hlo import op_census

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_roofline.json"
RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

F32 = 4  # bytes


def imm_useful_flops(n: int, m: int, K: int) -> float:
    """Per-track IMM frame mul/adds: K model-conditioned KF steps plus
    the mixing moment spread (K^2 weighted (P + x x^T) accumulations)
    and the moment-matched combination."""
    mix = K * K * (2 * n * n + 2 * n) + K * (2 * n * n + 2 * n)
    return K * useful_flops(n, m) + mix


def _cost_of(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4: [dict] per device
        ca = ca[0] if ca else {}
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)))


def _row(csv: List[str], rows: list, name: str, fn, args, pallas: bool,
         model_flops: float, model_bytes: float, machine,
         cost_probe=None) -> None:
    """Compile + census + time one program; append the csv/json row.

    ``cost_probe=(probe_fn, probe_args, scale)`` overrides the
    flops/bytes source: XLA's ``cost_analysis()`` counts a ``lax.scan``
    body ONCE (analysis.py's documented caveat), so scan-over-time
    programs cost the per-frame body and scale by T instead of trusting
    the scan program's own (T-independent) counters.
    """
    jfn = jax.jit(fn)
    compiled = jfn.lower(*args).compile()
    census = op_census(compiled.as_text())
    if cost_probe is not None:
        probe_fn, probe_args, scale = cost_probe
        cost = _cost_of(compiled_of(probe_fn, *probe_args))
        cost = dict(flops=cost["flops"] * scale, bytes=cost["bytes"] * scale)
    else:
        cost = _cost_of(compiled)
    sec = min(time_fn(jfn, *args, iters=3, warmup=1) for _ in range(3))
    terms = terms_on(machine, cost["flops"], cost["bytes"],
                     model_flops_dev=model_flops)
    model_terms = terms_on(machine, model_flops, model_bytes,
                           model_flops_dev=model_flops)
    row = dict(
        name=name, **row_mode(pallas),
        measured_us=sec * 1e6,
        hlo_flops=cost["flops"], hlo_bytes=cost["bytes"],
        model_flops=model_flops, model_bytes=model_bytes,
        useful_fraction=(model_flops / cost["flops"]
                         if cost["flops"] else 0.0),
        intensity_hlo=(cost["flops"] / cost["bytes"]
                       if cost["bytes"] else 0.0),
        intensity_model=(model_flops / model_bytes
                         if model_bytes else 0.0),
        t_compute_us=terms.t_compute * 1e6,
        t_memory_us=terms.t_memory * 1e6,
        dominant=terms.dominant,
        bound_us=model_terms.bound * 1e6,
        roofline_fraction=(model_terms.bound / sec if sec else 0.0),
        achieved_gflops=cost["flops"] / sec / 1e9 if sec else 0.0,
        cost_probe=("per-step-x-T" if cost_probe is not None
                    else "whole-program"),
        op_census=census,
    )
    rows.append(row)
    csv.append(
        f"roofline/{name},{sec * 1e6:.1f},"
        f"mode={row['mode']};lowering={row['lowering']};"
        f"useful={row['useful_fraction']:.4f};dom={row['dominant']};"
        f"roofline_frac={row['roofline_fraction']:.4f}")


def run(csv: List[str], Ns=(256,), T: int = 32, C: int = 256,
        M: int = 64) -> None:
    mode = active_mode()
    machine = machine_for_backend(mode.backend)
    rows: list = []
    lkf = get_filter("lkf")
    imm = make_imm()
    rng = np.random.default_rng(11)

    for N in Ns:
        zs = jnp.asarray(rng.normal(size=(T, N, lkf.m)) * 0.5, jnp.float32)
        x0 = jnp.asarray(np.tile(lkf.x0, (N, 1)), jnp.float32)
        P0 = jnp.asarray(np.tile(lkf.P0, (N, 1, 1)), jnp.float32)
        kf_flops = useful_flops(lkf.n, lkf.m) * N * T
        scan_bytes = (T * N * (lkf.m + lkf.n) * F32
                      + 2 * N * (lkf.n + lkf.n * lkf.n) * F32)

        # the fused Pallas scan — the kernel whose compiled-mode truth
        # this whole file exists to report
        _row(csv, rows, f"fused_scan/N={N}",
             lambda zs, x0, P0: katana_bank_sequence(
                 lkf, zs, x0, P0, interpret=mode.interpret),
             (zs, x0, P0), True, kf_flops, scan_bytes, machine)

        # the XLA-native twin: compiled code on every backend
        lanes_step, _ = build_stage(lkf, "batched_lanes", N=N)

        def lanes_scan(zs, x0, P0):
            def body(carry, z_t):
                x, P = lanes_step(*carry, z_t)
                return (x, P), x
            _, xs = jax.lax.scan(body, (x0, P0), zs)
            return xs

        _row(csv, rows, f"lanes_scan/N={N}", lanes_scan, (zs, x0, P0),
             False, kf_flops, scan_bytes, machine,
             cost_probe=(lanes_step, (x0, P0, zs[0]), T))

        # the fused IMM scan (mixing + mode posterior in-kernel)
        zs9 = jnp.asarray(rng.normal(size=(T, N, imm.m)) * 0.5, jnp.float32)
        x9 = jnp.asarray(np.tile(imm.models[0].x0, (N, 1)), jnp.float32)
        P9 = jnp.asarray(np.tile(imm.models[0].P0, (N, 1, 1)), jnp.float32)
        imm_flops = imm_useful_flops(imm.n, imm.m, imm.K) * N * T
        imm_bytes = (T * N * (imm.m + imm.n) * F32
                     + 2 * imm.K * N * (imm.n + imm.n * imm.n) * F32
                     + 2 * imm.K * N * F32)
        _row(csv, rows, f"imm_scan/N={N}",
             lambda zs, x0, P0: katana_imm_sequence(
                 imm, zs, x0, P0, interpret=mode.interpret),
             (zs9, x9, P9), True, imm_flops, imm_bytes, machine)

    # the live frame, both routes through tracker.frame_step — one
    # frame's measurement cycle incl. gating + assignment + lifecycle
    from benchmarks.frame import _init, _scene_frames, _steps
    from repro.core.tracker import TrackerConfig

    cfg_f = TrackerConfig(capacity=C, max_meas=M)
    cfg_e = dataclasses.replace(cfg_f, fused_frame=False)
    n_targets = max(2, min(M - 2, C // 4, 24))
    z, v = _scene_frames(lkf.m, M, 4, n_targets, seed=13)
    frame_flops = (useful_flops(lkf.n, lkf.m) * C
                   + C * M * (2 * lkf.m * lkf.m + 2 * lkf.m))
    frame_bytes = (2 * C * (lkf.n + lkf.n * lkf.n) * F32
                   + M * lkf.m * F32 + 2 * C * F32)
    for name, cfg, pallas in (("frame_fused", cfg_f, True),
                              ("frame_einsum", cfg_e, False)):
        step = _steps(lkf, cfg)
        bank = _init(lkf, cfg)
        for t in range(3):
            bank = step(bank, jnp.asarray(z[t]), jnp.asarray(v[t])).bank
        zt, vt = jnp.asarray(z[3]), jnp.asarray(v[3])
        _row(csv, rows, f"{name}/C={C}",
             lambda b, zz, vv: step(b, zz, vv).bank.x, (bank, zt, vt),
             pallas, frame_flops, frame_bytes, machine)

    # a natively-compiled Pallas variant is a different program than the
    # interpreter emulation — say so explicitly instead of pretending
    # the interpreted census covers it
    if not mode.pallas_native:
        for name in ("fused_scan", "imm_scan", "frame_fused"):
            csv.append(f"roofline/{name}/pallas-compiled,0,"
                       f"skip=pallas-lowering-unsupported:{mode.backend}")

    dryrun_note = _legacy_dryrun(csv)

    BENCH_JSON.write_text(json.dumps(dict(
        bench="roofline", meta=bench_meta(),
        machine=dict(name=machine.name, peak_flops=machine.peak_flops,
                     mem_bw=machine.mem_bw),
        T=T, C=C, M=M, rows=rows, dryrun=dryrun_note,
        notes=("useful_fraction = analytic mul/add floor / compiled HLO "
               "flops (cost_analysis). mode=interpret rows census the "
               "Pallas interpreter's EMULATED op stream — the number "
               "that makes interpret-vs-compiled conflation visible; "
               "mode=compiled rows (xla lowering on CPU, pallas on "
               "TPU/GPU) are real compiled code. bound_us is the "
               "three-term roofline on the backend Machine from the "
               "analytic floor; roofline_fraction = bound/measured."),
    ), indent=2) + "\n")


def _legacy_dryrun(csv: List[str]) -> str:
    """The old results/dryrun reader: explicit skip row when absent
    (always, in this repo) instead of silently contributing nothing."""
    cells = load_cells("single") + load_cells("multi")
    if not cells:
        csv.append("roofline/dryrun,0,skip=no results/dryrun artifacts "
                   "(repro.launch.dryrun writes them)")
        return "skipped: no results/dryrun artifacts"
    for rec in cells:
        tag = f"roofline/{rec['mesh']}/{rec['arch']}/{rec['shape']}"
        if not rec.get("supported", True):
            csv.append(f"{tag},0,skip={rec['skip_reason']}")
            continue
        r = rec.get("roofline")
        if not r:
            csv.append(f"{tag},0,no-probe")
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        csv.append(
            f"{tag},{bound * 1e6:.0f},"
            f"tc={r['t_compute_s']:.4f};tm={r['t_memory_s']:.4f};"
            f"tcoll={r['t_collective_s']:.4f};dom={r['dominant']};"
            f"useful={r['useful_fraction']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f}")
    return f"{len(cells)} dryrun cells"


def load_cells(mesh: str):
    cells = []
    root = RESULTS / mesh
    if not root.exists():
        return cells
    for f in sorted(root.glob("*/*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def table(mesh: str = "single") -> str:
    """Markdown table for EXPERIMENTS.md (dry-run cells)."""
    rows = [
        "| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant "
        "| useful | roofline frac | fits 16G (tpu-est) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(mesh):
        if not rec.get("supported", True):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped: {rec['skip_reason']} | — | — | — |")
            continue
        r = rec.get("roofline", {})
        f = rec.get("full", {})
        if not r:
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['t_compute_s']:.4g} "
            f"| {r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} "
            f"| {r['dominant']} | {r['useful_fraction']:.3f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {f.get('fits_16g_tpu_est', '—')} |")
    return "\n".join(rows)
