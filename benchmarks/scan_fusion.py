"""Scan-fusion bench: the cost of dispatch granularity on the hot path.

Three ways to filter the same (T, N, m) stream, timed end-to-end:

  ``step_loop``   T dispatches of the per-frame ``katana_bank`` kernel —
        the covariance bank round-trips HBM (and the AoS<->SoA
        transposes + lane padding are re-paid) every frame.
  ``fused_scan``  ONE ``katana_bank_sequence`` dispatch: time loop
        inside the kernel, x/P resident across frames, layout work paid
        once per sequence.
  ``lanes_scan``  the batched_lanes einsum stage under one jitted
        lax.scan — the XLA (non-Pallas) reference point.

Reported per (filter kind, N): per-frame latency (us) and frame
throughput (steps/sec), plus the fused-vs-step_loop speedup. Results
also land in BENCH_scan.json at the repo root so the perf trajectory of
the core workload is tracked from this PR onward.

Every row is stamped with how it actually executed (mode / lowering /
backend, see benchmarks/common.row_mode): on a CPU container the Pallas
rows are interpret-mode — dispatch + interpreter overhead, not TPU
silicon, which is exactly the axis the fused rewrite removes — while
``lanes_scan`` is real compiled XLA on every backend. Never compare an
interpret row against a compiled row without reading the stamp.
"""
from __future__ import annotations

import json
import pathlib
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_meta, row_mode, row_tag, time_fn
from repro.core.filters import get_filter
from repro.core.rewrites import build_stage
from repro.kernels.katana_bank.ops import katana_bank, katana_bank_sequence

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_scan.json"


def _inputs(model, N: int, T: int):
    rng = np.random.default_rng(N + T)
    zs = jnp.asarray(rng.normal(size=(T, N, model.m)) * 0.5, jnp.float32)
    x0 = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P0 = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    return zs, x0, P0


def run(csv: List[str], Ns=(64, 256, 1024), T: int = 32) -> None:
    rows = []
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        for N in Ns:
            zs, x0, P0 = _inputs(model, N, T)

            def step_loop(zs=zs, x0=x0, P0=P0):
                x, P = x0, P0
                for t in range(T):
                    x, P = katana_bank(model, x, P, zs[t])
                return x

            def fused(zs=zs, x0=x0, P0=P0):
                return katana_bank_sequence(model, zs, x0, P0)

            lanes_step, _ = build_stage(model, "batched_lanes", N=N)

            @jax.jit
            def lanes_scan(zs=zs, x0=x0, P0=P0):
                def body(carry, z_t):
                    x, P = lanes_step(*carry, z_t)
                    return (x, P), x
                _, xs = jax.lax.scan(body, (x0, P0), zs)
                return xs

            timings = {}
            # step_loop/fused_scan dispatch Pallas kernels; lanes_scan is
            # XLA-native — their per-row mode stamps differ on CPU
            for name, fn, pallas in (("step_loop", step_loop, True),
                                     ("fused_scan", fused, True),
                                     ("lanes_scan", lanes_scan, False)):
                sec = time_fn(fn, iters=3, warmup=1)
                per_frame_us = sec / T * 1e6
                steps_per_sec = T / sec
                timings[name] = dict(us_per_frame=per_frame_us,
                                     steps_per_sec=steps_per_sec,
                                     **row_mode(pallas))
                csv.append(f"scan_fusion/{kind}/{name}/N={N},"
                           f"{per_frame_us:.1f},"
                           f"steps_per_sec={steps_per_sec:.1f};"
                           f"{row_tag(pallas)}")
            speedup = (timings["fused_scan"]["steps_per_sec"]
                       / timings["step_loop"]["steps_per_sec"])
            csv.append(f"scan_fusion/{kind}/speedup_fused_vs_loop/N={N},0,"
                       f"x{speedup:.2f}")
            rows.append(dict(kind=kind, N=N, T=T, speedup_fused_vs_loop=speedup,
                             **{k: v for k, v in timings.items()}))
    BENCH_JSON.write_text(json.dumps(
        dict(bench="scan_fusion", meta=bench_meta(), T=T, rows=rows),
        indent=2) + "\n")
