"""Live-frame serving bench: the fused one-dispatch frame vs the einsum
chain.

This is the closed-loop number the paper reports (408.73 FPS LKF /
223.35 FPS EKF on Series 2 are per-frame measurement-in to
fused-estimate-out figures): one ``frame_step`` — predict + gate +
greedy assignment + update + lifecycle — per measurement frame. Rows
compare the two routes through the SAME ``tracker.frame_step`` /
``imm_frame_step``:

  * ``einsum``  — ``fused_frame=False``: the XLA chain predict_bank ->
    mahalanobis_cost -> greedy_assign -> update_bank (the PR-1 hot
    path, kept as the equivalence oracle);
  * ``fused``   — ``fused_frame=True``: ONE ``katana_frame`` /
    ``katana_imm_frame`` Pallas dispatch for the whole measurement
    cycle, with only spawn/prune left in XLA.

Single-sensor rows sweep the bank capacity C at a fixed measurement
budget M; the ``sharded`` rows run the 8-sensor ``ShardedBankEngine``
fleet (fused vs einsum) over however many host devices exist — run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the
8-device row (the bench-smoke CI job does; missing device counts emit
explicit ``skipped=`` rows, never silence).

Every timed configuration first asserts fused/einsum equivalence on
the timed frame (identical assoc, float32-tolerance states) — the CI
smoke run keeps that assertion at tiny shapes, where the timings
themselves are meaningless. Results land in BENCH_frame.json, every
row stamped with how it actually executed (mode / lowering / backend):
the ``einsum`` route is real compiled XLA on every backend, while the
``fused`` route's Pallas dispatch is interpret-stamped on CPU — those
numbers overweight dispatch/op overhead vs TPU silicon. Never read a
fused-vs-einsum speedup without reading the stamps first;
docs/benchmarks.md maps these FPS to the paper's reporting.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_meta, row_mode, row_tag, time_fn
from repro.core import bank as bank_lib
from repro.core.filters import get_filter, make_imm
from repro.core.tracker import TrackerConfig, frame_step, imm_frame_step

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_frame.json"

WARM_FRAMES = 6  # spawn + confirm tracks before the timed frame


def _scene_frames(m: int, M: int, T: int, n_targets: int, seed: int):
    """(T, M, m) measurement stream + validity: n_targets slow random
    walks in the first slots, the rest of the M budget empty — the
    static-shape serving frame shape."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_targets, m)).astype(np.float64) * 3.0
    z = np.zeros((T, M, m), np.float32)
    v = np.zeros((T, M), bool)
    for t in range(T):
        pos = pos + rng.normal(size=pos.shape) * 0.05
        z[t, :n_targets] = pos + rng.normal(size=pos.shape) * 0.05
        v[t, :n_targets] = True
    return z, v


def _steps(model, cfg: TrackerConfig):
    base = imm_frame_step if hasattr(model, "models") else frame_step
    return jax.jit(lambda b, z, v: base(model, cfg, b, z, v))


def _init(model, cfg: TrackerConfig):
    if hasattr(model, "models"):
        return bank_lib.init_imm_bank(model, cfg.capacity)
    return bank_lib.init_bank(model, cfg.capacity)


def _bench_single(csv: List[str], rows: list, kind: str, model, C: int,
                  M: int) -> None:
    cfg_f = TrackerConfig(capacity=C, max_meas=M)
    cfg_e = dataclasses.replace(cfg_f, fused_frame=False)
    step_f, step_e = _steps(model, cfg_f), _steps(model, cfg_e)
    n_targets = max(2, min(M - 2, C // 4, 24))
    z, v = _scene_frames(model.m, M, WARM_FRAMES + 1, n_targets, seed=5)
    bank = _init(model, cfg_f)
    for t in range(WARM_FRAMES):
        bank = step_f(bank, jnp.asarray(z[t]), jnp.asarray(v[t])).bank
    zt, vt = jnp.asarray(z[WARM_FRAMES]), jnp.asarray(v[WARM_FRAMES])
    # equivalence gate before anything is timed: identical association,
    # float32-tolerance states (the CI smoke run keeps only this part)
    rf, re = step_f(bank, zt, vt), step_e(bank, zt, vt)
    np.testing.assert_array_equal(np.asarray(rf.assoc), np.asarray(re.assoc))
    np.testing.assert_allclose(np.asarray(rf.bank.x), np.asarray(re.bank.x),
                               atol=5e-4, rtol=5e-4)
    row = dict(kind=kind, C=C, M=M, active=int(np.asarray(bank.active).sum()))
    for name, step in (("fused", step_f), ("einsum", step_e)):
        fn = lambda s=step: s(bank, zt, vt).bank.x
        # best-of-rounds: min is robust to the container's noisy
        # scheduler (the protocol every other bench here uses; 5 rounds
        # because the frame's sequential assignment loop is the most
        # stall-sensitive thing in the repo)
        sec = min(time_fn(fn, iters=5, warmup=1) for _ in range(5))
        pallas = name == "fused"  # einsum route is XLA on every backend
        row[name] = dict(us_per_frame=sec * 1e6, steps_per_sec=1.0 / sec,
                         **row_mode(pallas))
        csv.append(f"frame/{kind}/{name}/C={C},{sec * 1e6:.1f},"
                   f"steps_per_sec={1.0 / sec:.1f};{row_tag(pallas)}")
    row["speedup_fused_vs_einsum"] = (row["fused"]["steps_per_sec"]
                                      / row["einsum"]["steps_per_sec"])
    csv.append(f"frame/{kind}/speedup_fused_vs_einsum/C={C},0,"
               f"x{row['speedup_fused_vs_einsum']:.2f}")
    rows.append(row)


def _bench_sharded(csv: List[str], out: list, S: int, T: int) -> None:
    """8-sensor IMM fleet frames/sec, fused vs einsum frame route,
    over 1/8 host devices (``ShardedBankEngine``; one frame = all S
    sensors serviced)."""
    from repro.compat import make_mesh
    from repro.serving.engine import ShardedBankEngine

    imm = make_imm()
    n_dev = len(jax.devices())
    rng = np.random.default_rng(7)
    cfg_f = TrackerConfig(capacity=16, max_meas=8)
    cfg_e = dataclasses.replace(cfg_f, fused_frame=False)
    z = np.zeros((T, S, cfg_f.max_meas, imm.m), np.float32)
    v = np.zeros((T, S, cfg_f.max_meas), bool)
    pos = rng.normal(size=(S, 2, imm.m)) * 3
    for t in range(T):
        pos = pos + 0.05
        z[t, :, :2] = pos + rng.normal(size=pos.shape) * 0.05
        v[t, :, :2] = True
    for d in (1, 8):
        if d > n_dev or S % d:
            csv.append(f"frame/sharded/devices={d}/S={S},0,"
                       f"skipped=need {d} devices dividing S={S}")
            out.append(dict(devices=d, S=S, skipped=True))
            continue
        mesh = make_mesh((d,), ("data",))
        row = dict(devices=d, S=S)
        results = {}
        for name, cfg in (("fused", cfg_f), ("einsum", cfg_e)):
            eng = ShardedBankEngine(imm, S, cfg, mesh=mesh)
            results[name] = res = []
            # the engine warms its compile in __init__; dropping frame 0
            # from the stats anyway makes the steady-state methodology
            # explicit (matches the single-sensor rows' warmup)
            res.append(eng.frame(z[0], v[0]))
            eng.stats = type(eng.stats)()
            for t in range(1, T):
                res.append(eng.frame(z[t], v[t]))
            fps = eng.stats.fps
            pallas = name == "fused"
            row[name] = dict(frames_per_sec=fps, **row_mode(pallas))
            csv.append(f"frame/sharded/{name}/devices={d}/S={S},"
                       f"{1e6 / fps:.1f},frames_per_sec={fps:.1f};"
                       f"{row_tag(pallas)}")
        # the same equivalence gate as the single-sensor rows, under the
        # mesh: identical association + ids, close combined states,
        # every frame (comparisons happen outside eng.frame, so the
        # timed stats are untouched)
        for rf, re in zip(results["fused"], results["einsum"]):
            np.testing.assert_array_equal(np.asarray(rf.assoc),
                                          np.asarray(re.assoc))
            np.testing.assert_array_equal(np.asarray(rf.bank.track_id),
                                          np.asarray(re.bank.track_id))
            np.testing.assert_allclose(np.asarray(rf.x_est),
                                       np.asarray(re.x_est),
                                       atol=5e-4, rtol=5e-4)
        row["speedup_fused_vs_einsum"] = (row["fused"]["frames_per_sec"]
                                          / row["einsum"]["frames_per_sec"])
        out.append(row)


def run(csv: List[str], Cs=(64, 256, 1024), M: int = 64,
        sensors: int = 8, sensor_frames: int = 24) -> None:
    rows: list = []
    models = (("lkf", get_filter("lkf")), ("imm", make_imm()))
    for kind, model in models:
        for C in Cs:
            _bench_single(csv, rows, kind, model, C, M)
    sharded: list = []
    _bench_sharded(csv, sharded, sensors, sensor_frames)
    headline = next((r["speedup_fused_vs_einsum"] for r in rows
                     if r["kind"] == "lkf" and r["C"] == 256), None)
    BENCH_JSON.write_text(json.dumps(dict(
        bench="frame", meta=bench_meta(), M=M,
        rows=rows, sharded=sharded,
        speedup_lkf_c256=headline,
        notes=("fused = one katana_frame/katana_imm_frame Pallas "
               "dispatch per frame (TrackerConfig.fused_frame, the "
               "serving default); einsum = the predict_bank -> "
               "mahalanobis_cost -> greedy_assign -> update_bank XLA "
               "chain (equivalence oracle). Every row asserts identical "
               "assoc + float32-tolerance states before timing. "
               "sharded rows: 8-sensor IMM ShardedBankEngine fleet "
               "frames/sec. Read each row's mode/lowering stamp: "
               "einsum rows are compiled XLA everywhere, fused rows "
               "are interpret-stamped on CPU (overweighting per-op "
               "dispatch overhead vs TPU silicon); see "
               "docs/benchmarks.md for the paper-FPS mapping."),
    ), indent=2) + "\n")
