"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (0 in the us column for
pure-analysis rows).

  PYTHONPATH=src python -m benchmarks.run [--only table1,fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback
from typing import List

ALL = ("accuracy", "fig4", "batching", "table1", "roofline", "scan_fusion",
       "imm")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(ALL))
    args = ap.parse_args(argv)
    wanted = [w for w in args.only.split(",") if w]
    csv: List[str] = []
    failed = []
    for name in wanted:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(csv)
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            traceback.print_exc()
    print("name,us_per_call,derived")
    for line in csv:
        print(line)
    if failed:
        print(f"\n{len(failed)} bench module(s) failed:", file=sys.stderr)
        for n, e in failed:
            print(f"  {n}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
