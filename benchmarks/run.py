"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (0 in the us column for
pure-analysis rows).

  PYTHONPATH=src python -m benchmarks.run [--only table1,fig4,...] [--smoke]

``--smoke`` runs the drivers that accept shape parameters at tiny
shapes (T<=8, a handful of tracks) — the CI benchmark-smoke job uses it
to prove every driver still imports, runs and writes its BENCH json
without paying full benchmark time. Smoke numbers are NOT meaningful
perf data; don't commit the resulting json.
"""
from __future__ import annotations

import argparse
import sys
import traceback
from typing import List

ALL = ("accuracy", "fig4", "batching", "table1", "roofline", "scan_fusion",
       "imm", "frame", "serving")

SMOKE_KWARGS = {
    # roofline: the census/cost_analysis wiring is the point; tiny
    # shapes keep the compiles cheap while still emitting every row
    "roofline": dict(Ns=(8,), T=8, C=16, M=8),
    "scan_fusion": dict(Ns=(8,), T=8),
    "imm": dict(N=4, T=8),
    # keeps the HLO-census rows small AND drives the sharded-IMM serving
    # rows at a 4-sensor fleet over however many host devices exist
    "batching": dict(N=8, imm_sensors=4, imm_frames=4),
    # tiny shapes: the fused-vs-einsum frame equivalence assert is the
    # point in CI; the timings at these shapes are not perf data.
    # sensors=8 so the 8-device sharded row actually runs under the
    # bench-smoke job's forced 8-device host platform
    "frame": dict(Cs=(16,), M=8, sensors=8, sensor_frames=4),
    # deterministic behavior (fake clock + seeded scenes), so the
    # served/recovered fractions the regression gate pins are exact
    # at these shapes; the fps column is machine noise in CI
    "serving": dict(tenants=3, cycles=24),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(ALL))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes: exercise the drivers, not the perf")
    args = ap.parse_args(argv)
    wanted = [w for w in args.only.split(",") if w]
    csv: List[str] = []
    failed = []
    for name in wanted:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(csv, **(SMOKE_KWARGS.get(name, {}) if args.smoke else {}))
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            traceback.print_exc()
    print("name,us_per_call,derived")
    for line in csv:
        print(line)
    if failed:
        print(f"\n{len(failed)} bench module(s) failed:", file=sys.stderr)
        for n, e in failed:
            print(f"  {n}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
