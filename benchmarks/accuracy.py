"""Fig. 5 proxy: tracking accuracy per stage — every rewrite stage must
produce the SAME track (algebraic exactness), and the filter must beat
the raw measurements on its own dynamics."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import ref
from repro.core.filters import get_filter
from repro.core.rewrites import STAGES, run_sequence
from repro.data.trajectories import single_target


def run(csv: List[str]) -> None:
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        truth, zs = single_target(model, 200, seed=5)
        est_ref, _ = ref.run(model, zs)
        rmse_meas = float(np.sqrt(np.mean((zs[:, :3] - truth[:, :3]) ** 2)))
        rmse_ref = float(np.sqrt(np.mean(
            (est_ref[50:, :3] - truth[50:, :3]) ** 2)))
        csv.append(f"accuracy/{kind}/measurements,0,rmse={rmse_meas:.4f}")
        csv.append(f"accuracy/{kind}/oracle,0,rmse={rmse_ref:.4f}")
        for stage in STAGES:
            N = 1 if stage in ("baseline", "opt1", "opt2") else 1
            got = np.asarray(run_sequence(
                model, stage, zs[:, None, :], np.tile(model.x0, (1, 1)),
                np.tile(model.P0, (1, 1, 1))))[:, 0]
            dev = float(np.max(np.abs(got - est_ref)))
            csv.append(f"accuracy/{kind}/{stage},0,"
                       f"max_dev_vs_oracle={dev:.2e}")
