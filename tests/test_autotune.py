"""Autotuned tile table: lookup rules, advisory-only fallback, and the
bench-regression gate that rides the same BENCH artifacts.

The table contract (src/repro/kernels/katana_bank/autotune.py): exact
``backend/mode`` key match, nearest-N in log-space, and NO semantics —
a missing/garbage table must leave every op on its static defaults.
The regression gate contract (benchmarks/check_regression.py): ratio
floors keyed mode+shape, red on injected slowdown and on silently
dropped rows, green within tolerance.
"""
import json

import pytest

from repro.execmode import ExecMode
from repro.kernels.katana_bank import autotune

CPU_INTERP = ExecMode("auto", "interpret", "cpu", False, None, "x")
TPU_COMPILED = ExecMode("auto", "compiled", "tpu", True, None, "x")


@pytest.fixture
def table(tmp_path):
    path = tmp_path / "tuned.json"
    autotune.write_table({
        "katana_bank_sequence": {
            "cpu/interpret": [
                dict(N=64, lane_tile=128, time_chunk=1024, us_per_frame=1.0),
                dict(N=1024, lane_tile=512, time_chunk=4096,
                     us_per_frame=2.0),
            ],
        },
    }, path)
    yield path
    autotune.clear_cache()


def test_nearest_n_in_log_space(table):
    # N=100 is nearer 64 than 1024 in log space
    cfg = autotune.best_config("katana_bank_sequence", 100, CPU_INTERP,
                               path=table)
    assert cfg["lane_tile"] == 128
    # N=500: log(500/64)=2.06 vs log(1024/500)=0.72 -> 1024 wins
    cfg = autotune.best_config("katana_bank_sequence", 500, CPU_INTERP,
                               path=table)
    assert cfg["lane_tile"] == 512


def test_mode_key_is_exact(table):
    """A CPU/interpret entry never drives a TPU/compiled run."""
    assert autotune.best_config("katana_bank_sequence", 64, TPU_COMPILED,
                                path=table) == {}


def test_unknown_kernel_and_missing_table(tmp_path, table):
    assert autotune.best_config("nope", 64, CPU_INTERP, path=table) == {}
    missing = tmp_path / "absent.json"
    assert autotune.best_config("katana_bank_sequence", 64, CPU_INTERP,
                                path=missing) == {}


def test_tuned_helpers_fall_back_to_default(tmp_path):
    autotune.clear_cache()
    missing = tmp_path / "absent.json"
    # helpers consult the module TUNED_PATH; drive best_config directly
    assert autotune.best_config("katana_bank", 64, CPU_INTERP,
                                path=missing) == {}
    # a zero/absent field in a hit falls back too
    path = tmp_path / "t.json"
    autotune.write_table({"katana_bank": {"cpu/interpret": [
        dict(N=64, lane_tile=0, us_per_frame=1.0)]}}, path)
    cfg = autotune.best_config("katana_bank", 64, CPU_INTERP, path=path)
    assert (int(cfg.get("lane_tile", 0)) or 256) == 256
    autotune.clear_cache()


def test_bad_format_table_is_ignored(tmp_path):
    autotune.clear_cache()
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(dict(format=999, entries={
        "katana_bank": {"cpu/interpret": [dict(N=1, lane_tile=8)]}})))
    assert autotune.best_config("katana_bank", 1, CPU_INTERP,
                                path=path) == {}
    path.write_text("{not json")
    autotune.clear_cache()
    assert autotune.best_config("katana_bank", 1, CPU_INTERP,
                                path=path) == {}
    autotune.clear_cache()


def test_checked_in_table_is_well_formed():
    """The committed tuned.json must parse under the current format and
    only contain known kernels with positive tile values."""
    doc = json.loads(autotune.TUNED_PATH.read_text())
    assert doc["format"] == autotune.TABLE_FORMAT
    for kernel, by_key in doc["entries"].items():
        assert kernel in autotune.STATIC_DEFAULTS, kernel
        for key, rows in by_key.items():
            backend, mode = key.split("/")
            assert mode in ("interpret", "compiled")
            for r in rows:
                assert r["N"] > 0
                assert r.get("lane_tile", 0) >= 0
                assert r.get("time_chunk", 1) > 0
                assert r["us_per_frame"] > 0


def test_ops_defaults_consult_table(tmp_path, monkeypatch):
    """lane_tile=0 at the ops layer resolves through the table: point
    TUNED_PATH at a table pinning a non-default tile and check the op
    still produces correct output (the tile is a layout knob, never a
    semantics knob)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.filters import get_filter
    from repro.kernels.katana_bank.ops import katana_bank

    path = tmp_path / "tuned.json"
    autotune.write_table({"katana_bank": {"cpu/interpret": [
        dict(N=8, lane_tile=64, us_per_frame=1.0)]}}, path)
    monkeypatch.setattr(autotune, "TUNED_PATH", path)
    autotune.clear_cache()
    try:
        model = get_filter("lkf")
        N = 8
        rng = np.random.default_rng(2)
        x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
        P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(N, model.m)), jnp.float32)
        x_tuned, P_tuned = katana_bank(model, x, P, z, interpret=True)
        x_pinned, P_pinned = katana_bank(model, x, P, z, lane_tile=256,
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(x_tuned),
                                   np.asarray(x_pinned),
                                   atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(P_tuned),
                                   np.asarray(P_pinned),
                                   atol=1e-6, rtol=1e-6)
    finally:
        autotune.clear_cache()


# ---------------------------------------------------------------------------
# bench-regression gate
# ---------------------------------------------------------------------------

def _bench_fixture(root, speedup_scan=4.0, speedup_frame=1.5,
                   imm_ratio=2.0, drop_frame=False):
    meta = dict(requested="auto", mode="interpret", backend="cpu",
                pallas_native=False, fallback=None, jax="x")
    (root / "BENCH_scan.json").write_text(json.dumps(dict(
        bench="scan_fusion", meta=meta,
        rows=[dict(kind="lkf", N=8, speedup_fused_vs_loop=speedup_scan)])))
    (root / "BENCH_imm.json").write_text(json.dumps(dict(
        bench="imm", meta=meta, N=4,
        ratio_kernel_imm_vs_cv9=0.5,
        speedup_imm_scan_vs_per_frame=imm_ratio,
        ratio_imm_scan_vs_ref=0.6)))
    if not drop_frame:
        (root / "BENCH_frame.json").write_text(json.dumps(dict(
            bench="frame", meta=meta,
            rows=[dict(kind="lkf", C=16,
                       speedup_fused_vs_einsum=speedup_frame)],
            sharded=[dict(devices=8, S=8, skipped=True)])))


def test_gate_green_within_tolerance(tmp_path):
    from benchmarks.check_regression import check, collect

    _bench_fixture(tmp_path)
    baseline = collect(tmp_path)
    assert baseline  # the fixture produced pinnable ratios
    # 10% slower is inside the 25% band
    _bench_fixture(tmp_path, speedup_scan=3.6, speedup_frame=1.4)
    failures, _ = check(baseline, collect(tmp_path), tol=0.25)
    assert failures == []


def test_gate_red_on_injected_slowdown(tmp_path):
    """The acceptance demo: a de-fused scan (speedup collapses toward
    1x) must turn the gate red."""
    from benchmarks.check_regression import check, collect

    _bench_fixture(tmp_path, speedup_scan=4.0)
    baseline = collect(tmp_path)
    _bench_fixture(tmp_path, speedup_scan=1.1)  # injected slowdown
    failures, _ = check(baseline, collect(tmp_path), tol=0.25)
    assert any("REGRESSED" in f and "fused_vs_loop" in f for f in failures)


def test_gate_red_on_dropped_row(tmp_path):
    """A bench row that silently disappears must not pass."""
    from benchmarks.check_regression import check, collect

    _bench_fixture(tmp_path)
    baseline = collect(tmp_path)
    _bench_fixture(tmp_path, drop_frame=True)
    (tmp_path / "BENCH_frame.json").unlink()
    failures, _ = check(baseline, collect(tmp_path), tol=0.25)
    assert any("MISSING" in f and "fused_vs_einsum" in f for f in failures)


def test_gate_keys_are_mode_scoped(tmp_path):
    """An interpret-mode baseline never judges a compiled run: the key
    prefix separates them, so the compiled run shows up as MISSING (pin
    it separately), not as a bogus pass/fail against interpret floors."""
    from benchmarks.check_regression import check, collect

    _bench_fixture(tmp_path)
    baseline = collect(tmp_path)
    assert all(k.startswith("cpu/interpret/") for k in baseline)
    compiled_meta_doc = json.loads((tmp_path / "BENCH_scan.json").read_text())
    compiled_meta_doc["meta"]["mode"] = "compiled"
    (tmp_path / "BENCH_scan.json").write_text(json.dumps(compiled_meta_doc))
    failures, _ = check(baseline, collect(tmp_path), tol=0.25)
    assert any("MISSING" in f and "scan_fusion" in f for f in failures)


def test_committed_baseline_parses():
    from benchmarks.check_regression import BASELINE_PATH

    doc = json.loads(BASELINE_PATH.read_text())
    assert doc["ratios"], "committed baseline must pin at least one ratio"
    for key, val in doc["ratios"].items():
        backend, mode = key.split("/")[:2]
        assert mode in ("interpret", "compiled")
        assert val > 0
