"""IMM multi-model bank: kernel vs oracles, degenerate cases, tracker
integration, and the accuracy claim on the maneuvering-target scene."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref as oref
from repro.core.filters import (as_imm, get_filter, make_ca9_lkf,
                                make_ct9_lkf, make_cv9_lkf, make_imm)
from repro.core.rewrites import imm_combine, imm_mix, run_sequence, small_det
from repro.core.tracker import TrackerConfig, make_jitted_imm_tracker
from repro.data.trajectories import maneuvering_batch, maneuvering_target
from repro.kernels.katana_bank.kernel import plan_imm_tables
from repro.kernels.katana_bank.ops import (imm_bank_sequence, katana_bank_imm,
                                           katana_bank_sequence)
from repro.kernels.katana_bank.ref import katana_imm_ref


def _random_states(imm, N, seed=0):
    rng = np.random.default_rng(seed)
    K, n, m = imm.K, imm.n, imm.m
    x = jnp.asarray(rng.normal(size=(K, N, n)), jnp.float32)
    A = rng.normal(size=(K, N, n, n)) * 0.3
    P = jnp.asarray(A @ A.transpose(0, 1, 3, 2) + np.eye(n), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, m)), jnp.float32)
    return x, P, z


# ------------------------------------------------------------ kernel step
@pytest.mark.parametrize("N", [1, 7, 64, 130])  # incl. non-tile multiples
def test_imm_kernel_matches_jnp_ref(N):
    """Stacked-lane multi-model kernel == per-model einsum oracle,
    states, covariances AND log-likelihoods (the kernel's Sinv/det reuse
    is exact)."""
    imm = make_imm()
    x, P, z = _random_states(imm, N, seed=N)
    xk, Pk, llk = katana_bank_imm(imm, x, P, z, lane_tile=128)
    xr, Pr, llr = katana_imm_ref(imm, x, P, z)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(Pk), np.asarray(Pr),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(llk), np.asarray(llr),
                               atol=5e-5, rtol=2e-4)


def test_plan_imm_tables_folds_shared_entries():
    """Entries identical across models stay trace-time floats; only the
    genuinely differing entries consume table rows."""
    imm = make_imm()
    entries, V = plan_imm_tables(imm.models)
    # R is identical for every member model -> fully folded
    assert all(isinstance(c, float) for row in entries["R"] for c in row)
    # F differs (CV/CA/CT dynamics) -> some varying entries exist
    f_vars = [c for row in entries["F"] for c in row if not
              isinstance(c, float)]
    assert f_vars, "expected varying F entries across CV/CA/CT"
    # every varying reference resolves into V
    for tag, e in f_vars:
        assert tag == "var" and 0 <= e < V.shape[0]
    # shared diagonal example: F[5][5] == 1.0 in all four models
    assert entries["F"][5][5] == 1.0


# ----------------------------------------------------- sequence vs oracle
def test_imm_sequence_matches_float64_oracle():
    """imm_bank (mix -> fused kernel -> mode posterior) tracks the
    textbook float64 IMM recursion at fused-scan tolerances."""
    imm = make_imm()
    rng = np.random.default_rng(3)
    T, N = 60, 5
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    x0 = np.tile(imm.x0, (N, 1))
    P0 = np.tile(imm.P0, (N, 1, 1))
    want, _ = oref.run_imm_batched(imm, zs, x0, P0)
    got = np.asarray(imm_bank_sequence(
        imm, jnp.asarray(zs, jnp.float32), jnp.asarray(x0, jnp.float32),
        jnp.asarray(P0, jnp.float32), lane_tile=128))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("kind", ["cv9", "ekf"])
def test_imm_k1_reduces_to_plain_bank(kind):
    """K=1 IMM == the existing single-model fused bank (mixing with one
    mode is the identity; mu stays 1) — including the nonlinear EKF
    member via the K=1 kernel delegation."""
    model = get_filter(kind)
    rng = np.random.default_rng(7)
    T, N = 40, 6
    zs = jnp.asarray(rng.normal(size=(T, N, model.m)) * 0.5, jnp.float32)
    x0 = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P0 = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    got = np.asarray(imm_bank_sequence(as_imm(model), zs, x0, P0,
                                       lane_tile=128))
    plain = np.asarray(katana_bank_sequence(model, zs, x0, P0,
                                            lane_tile=128))
    np.testing.assert_allclose(got, plain, atol=1e-6, rtol=1e-6)


def test_imm_stage_in_run_sequence():
    """The 'imm_bank' rewrites stage is driveable through the uniform
    run_sequence entry point with an IMMModel."""
    imm = make_imm()
    rng = np.random.default_rng(11)
    T, N = 30, 4
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    x0 = np.tile(imm.x0, (N, 1))
    P0 = np.tile(imm.P0, (N, 1, 1))
    got = np.asarray(run_sequence(imm, "imm_bank", zs, x0, P0))
    want, _ = oref.run_imm_batched(imm, zs, x0, P0)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------- IMM algebra
def test_imm_mix_preserves_normalization_and_psd():
    """Mixing keeps mode probabilities normalized and mixed covariances
    PSD (the spread term does its job)."""
    imm = make_imm()
    K, n = imm.K, imm.n
    rng = np.random.default_rng(5)
    B = 6
    x = jnp.asarray(rng.normal(size=(K, B, n)), jnp.float32)
    A = rng.normal(size=(K, B, n, n)) * 0.3
    P = jnp.asarray(A @ A.transpose(0, 1, 3, 2) + np.eye(n), jnp.float32)
    mu = rng.random((B, K)) + 0.1
    mu = jnp.asarray(mu / mu.sum(1, keepdims=True), jnp.float32)
    x_mix, P_mix, cbar = imm_mix(x, P, mu, jnp.asarray(imm.trans, jnp.float32))
    np.testing.assert_allclose(np.asarray(cbar).sum(1), 1.0, atol=1e-6)
    Pm = np.asarray(P_mix)
    for k in range(K):
        for b in range(B):
            np.testing.assert_allclose(Pm[k, b], Pm[k, b].T, atol=1e-5)
            assert np.linalg.eigvalsh(Pm[k, b]).min() > -1e-4


def test_imm_mix_survives_unreachable_mode():
    """A mode the chain cannot reach (identity transition + zero mode
    probability) must not divide 0/0 into NaN: mixing stays finite and
    the dead mode's posterior weight stays exactly 0."""
    import numpy as _np

    from repro.core.filters import IMMModel
    from repro.core.rewrites import imm_mode_posterior

    cv = make_cv9_lkf()
    ca = make_ca9_lkf()
    imm = IMMModel(name="frozen", models=(cv, ca), trans=_np.eye(2),
                   mu0=_np.array([1.0, 0.0]))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 9)),
                    jnp.float32)
    P = jnp.broadcast_to(jnp.eye(9), (2, 3, 9, 9)).astype(jnp.float32)
    mu = jnp.asarray(np.tile(imm.mu0, (3, 1)), jnp.float32)
    x_mix, P_mix, cbar = imm_mix(x, P, mu, jnp.asarray(imm.trans,
                                                       jnp.float32))
    assert np.isfinite(np.asarray(x_mix)).all()
    assert np.isfinite(np.asarray(P_mix)).all()
    mu2 = imm_mode_posterior(cbar, jnp.zeros((2, 3), jnp.float32))
    np.testing.assert_allclose(np.asarray(mu2), np.tile([1.0, 0.0], (3, 1)),
                               atol=0)


def test_small_det_matches_numpy():
    rng = np.random.default_rng(2)
    for dim in (1, 2, 3, 4):
        A = rng.normal(size=(16, dim, dim))
        A = A @ np.swapaxes(A, -1, -2) + 3 * np.eye(dim)
        got = np.asarray(small_det(jnp.asarray(A, jnp.float32), dim))
        np.testing.assert_allclose(got, np.linalg.det(A), rtol=1e-4)


# ------------------------------------------------------------ accuracy win
def test_imm_beats_single_cv_on_maneuvering_scene():
    """The headline claim: on the CV/CT/CA switching scene the IMM bank
    has materially lower position RMSE than the single-model CV LKF
    (same claim benchmarks/imm.py records into BENCH_imm.json)."""
    T, N = 96, 8
    truth, zs = maneuvering_batch(T, N, seed=1)
    cv = get_filter("lkf")
    imm = make_imm()
    zsf = jnp.asarray(zs, jnp.float32)
    xc = jnp.asarray(np.tile(cv.x0, (N, 1)), jnp.float32)
    Pc = jnp.asarray(np.tile(cv.P0, (N, 1, 1)), jnp.float32)
    xi = jnp.asarray(np.tile(imm.x0, (N, 1)), jnp.float32)
    Pi = jnp.asarray(np.tile(imm.P0, (N, 1, 1)), jnp.float32)
    est_cv = np.asarray(katana_bank_sequence(cv, zsf, xc, Pc, lane_tile=128))
    est_imm = np.asarray(imm_bank_sequence(imm, zsf, xi, Pi, lane_tile=128))
    warm = 20

    def rmse(est):
        return np.sqrt(np.mean((est[warm:, :, :3] - truth[warm:, :, :3]) ** 2))

    assert rmse(est_imm) < 0.75 * rmse(est_cv), \
        (rmse(est_imm), rmse(est_cv))


def test_imm_mode_probs_follow_the_maneuver():
    """On a long coordinated-turn segment the CT hypotheses dominate the
    CV hypothesis (the mode chain identifies the maneuver)."""
    imm = make_imm(omega=0.7)
    T = 120
    rng = np.random.default_rng(0)
    # pure CT+ truth at exactly the model's turn rate
    p = np.zeros(3)
    v = np.array([3.0, 0.0, 0.0])
    dt, w = imm.dt, 0.7
    zs = np.zeros((T, 3))
    for t in range(T):
        c, s = np.cos(w * dt), np.sin(w * dt)
        v = np.array([c * v[0] - s * v[1], s * v[0] + c * v[1], v[2]])
        p = p + v * dt
        zs[t] = p + 0.05 * rng.normal(size=3)
    _, mus = oref.run_imm(imm, zs)
    # modes: 0=CV, 1=CA, 2=CT(+w), 3=CT(-w)
    assert mus[-1, 2] > mus[-1, 0]
    assert mus[-1, 2] > mus[-1, 3]


# ---------------------------------------------------------------- tracker
def test_imm_tracker_confirms_maneuvering_targets():
    imm = make_imm()
    cfg = TrackerConfig(capacity=16, max_meas=8)
    T, N = 60, 3
    truth, zs = maneuvering_batch(T, N, seed=5)
    init, step = make_jitted_imm_tracker(imm, cfg)
    bank = init()
    for t in range(T):
        z = np.zeros((cfg.max_meas, 3), np.float32)
        v = np.zeros(cfg.max_meas, bool)
        z[:N] = zs[t]
        v[:N] = True
        res = step(bank, jnp.asarray(z), jnp.asarray(v))
        bank = res.bank
    assert int(res.confirmed.sum()) == N
    # combined estimate lands near the truth for each confirmed track
    est = np.asarray(res.x_est)[np.asarray(res.confirmed)]
    err = np.abs(est[:, None, :3] - truth[-1][None, :, :3]).sum(-1).min(1)
    assert (err < 1.0).all(), err
    # mode probabilities are a distribution per track
    mu = np.asarray(res.mode_probs)[np.asarray(res.confirmed)]
    np.testing.assert_allclose(mu.sum(1), 1.0, atol=1e-5)


def test_imm_tracker_mode_probs_stay_normalized_under_coasting():
    """With no measurements at all (pure coasting) the mode probability
    update is the Markov prediction cbar — rows keep summing to 1 and
    never go NaN, until the tracks prune away."""
    imm = make_imm()
    cfg = TrackerConfig(capacity=8, max_meas=4, max_misses=20)
    init, step = make_jitted_imm_tracker(imm, cfg)
    bank = init()
    # spawn two tracks
    z = np.zeros((4, 3), np.float32)
    z[:2] = [[1.0, 2.0, 0.0], [-3.0, 0.5, 1.0]]
    v = np.array([True, True, False, False])
    res = step(bank, jnp.asarray(z), jnp.asarray(v))
    bank = res.bank
    # coast for 10 frames
    for _ in range(10):
        res = step(bank, jnp.zeros((4, 3), jnp.float32), jnp.zeros(4, bool))
        bank = res.bank
        mu = np.asarray(bank.mu)
        assert np.isfinite(mu).all()
        act = np.asarray(bank.active)
        assert act[:2].all()  # max_misses=20: still alive
        np.testing.assert_allclose(mu[act].sum(1), 1.0, atol=1e-5)


def test_imm_engine_snapshots_carry_mode_probs():
    from repro.serving.engine import TrackingEngine

    imm = make_imm()
    eng = TrackingEngine(imm, TrackerConfig(capacity=8, max_meas=4,
                                            min_hits=2))
    _, zs = maneuvering_target(30, seed=9)
    snaps = []
    for t in range(30):
        snaps = eng.submit(zs[t][None, :])
    assert len(snaps) == 1
    assert snaps[0].mode_probs is not None
    np.testing.assert_allclose(snaps[0].mode_probs.sum(), 1.0, atol=1e-5)
    assert snaps[0].state.shape == (imm.n,)
    # replay goes through imm_bank_sequence
    out = eng.replay(zs[:10][:, None, :])
    assert out.shape == (10, 1, imm.n)


def test_update_imm_bank_recompute_fallback_matches_passthrough():
    """``update_imm_bank``'s standalone path (z_pred/PHt/Sinv/S/cbar =
    None) rebuilds the innovation quantities from the predicted bank
    with the same expressions ``predict_imm_bank`` uses — updates must
    come out bit-identical to the pass-through, every combination of
    missing tensors."""
    from repro.core import bank as bank_lib

    imm = make_imm()
    rng = np.random.default_rng(5)
    C, M = 10, 5
    bank = bank_lib.init_imm_bank(imm, C)
    bank = bank._replace(
        active=jnp.asarray(rng.random(C) < 0.7),
        x=jnp.asarray(rng.normal(size=(imm.K, C, imm.n)) * 0.4, jnp.float32),
        mu=jnp.asarray(rng.dirichlet(np.ones(imm.K), C), jnp.float32))
    bank_p, z_pred, S, Sinv, PHt, cbar = bank_lib.predict_imm_bank(imm, bank)
    z = jnp.asarray(rng.normal(size=(M, imm.m)) * 0.4, jnp.float32)
    assoc = jnp.asarray(rng.integers(-1, M, size=C), jnp.int32)
    ref = bank_lib.update_imm_bank(imm, bank_p, z, assoc, z_pred, PHt, Sinv,
                                   S, cbar)
    cases = (
        dict(),                                              # all recomputed
        dict(z_pred=z_pred, PHt=PHt),                        # partial
        dict(z_pred=z_pred, PHt=PHt, Sinv=Sinv, S=S),        # only cbar
    )
    for kw in cases:
        got = bank_lib.update_imm_bank(imm, bank_p, z, assoc, **kw)
        np.testing.assert_array_equal(np.asarray(got.x), np.asarray(ref.x))
        np.testing.assert_array_equal(np.asarray(got.P), np.asarray(ref.P))
        np.testing.assert_array_equal(np.asarray(got.mu), np.asarray(ref.mu))
        np.testing.assert_array_equal(np.asarray(got.hits),
                                      np.asarray(ref.hits))
