"""Deliverable (e) in CI: the real dry-run CLI runs in a subprocess
(with the 512-device XLA flag set by the script itself) and must
lower+compile a production-mesh cell end to end."""
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_dryrun_cli_single_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m", "--shape", "long_500k",
         "--mesh", "multi", "--no-probes", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads(
        (tmp_path / "multi" / "mamba2-130m" / "long_500k.json").read_text())
    assert rec["supported"]
    assert rec["full"]["arg_bytes_dev"] > 0
    assert rec["full"]["compile_s"] > 0
