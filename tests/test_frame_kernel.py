"""Fused live-frame dispatch (katana_frame / katana_imm_frame).

The tentpole contract: routing ``frame_step`` / ``imm_frame_step``
through the single Pallas dispatch (``TrackerConfig.fused_frame``, the
default) changes NOTHING observable vs the einsum chain it replaces —
identical association and track ids frame-by-frame across full
spawn/coast/prune lifecycles, float32-tolerance states — and the
in-kernel wave-scheduled greedy assignment is EXACTLY
``tracker.greedy_assign`` (same gate, same tie-breaks, same -1
padding) on arbitrary cost matrices, ties and invalid padding
included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bank as bank_lib
from repro.core.filters import as_imm, get_filter, make_imm
from repro.core.tracker import (TrackerConfig, frame_step, greedy_assign,
                                imm_frame_step)
from repro.data.trajectories import SceneConfig, mot_scene
from repro.kernels.katana_bank.ops import (frame_kernel_supported,
                                           katana_greedy_assign)

CFG = TrackerConfig(capacity=32, max_meas=16)
CFG_EINSUM = dataclasses.replace(CFG, fused_frame=False)


# ---------------------------------------------------------------------------
# In-kernel greedy assignment == tracker.greedy_assign, exactly.
# ---------------------------------------------------------------------------

@given(st.integers(1, 9), st.integers(1, 9), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_prop_kernel_greedy_matches_reference(C, M, seed):
    """Random gated cost matrices — including exact ties (costs rounded
    to a half-unit grid) so the first-occurrence tie-break is really
    exercised: the wave-scheduled in-kernel assignment must equal the
    sequential reference element-for-element."""
    rng = np.random.default_rng(seed)
    cost = (np.round(rng.uniform(0, 10, (C, M)) * 2) / 2).astype(np.float32)
    valid = rng.random((C, M)) > 0.3
    # integer gate: the gate is a trace-time constant of the dispatch,
    # so a continuous draw would compile a fresh kernel per example
    gate = float(rng.integers(2, 9))
    rounds = min(C, M)
    ref = np.asarray(greedy_assign(jnp.asarray(cost), jnp.asarray(valid),
                                   jnp.asarray(gate), rounds))
    got = np.asarray(katana_greedy_assign(jnp.asarray(cost),
                                          jnp.asarray(valid), gate=gate,
                                          rounds=rounds))
    np.testing.assert_array_equal(got, ref)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 5),
       st.integers(0, 5), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_prop_kernel_greedy_invalid_padding(C, M, pad_c, pad_m, seed):
    """Invalid-padded rows (dead slots) and columns (empty measurement
    slots) with temptingly-cheap garbage costs change nothing: original
    slots keep their exact reference assignment, padding stays -1 —
    the static-shape serving contract for the in-kernel greedy."""
    rng = np.random.default_rng(seed)
    gate = 8.0
    cost = rng.uniform(0, 10, (C, M)).astype(np.float32)
    valid = rng.random((C, M)) > 0.3
    ref = np.asarray(greedy_assign(jnp.asarray(cost), jnp.asarray(valid),
                                   jnp.asarray(gate), min(C, M)))
    cost_p = rng.uniform(0, 1, (C + pad_c, M + pad_m)).astype(np.float32)
    cost_p[:C, :M] = cost
    valid_p = np.zeros((C + pad_c, M + pad_m), bool)
    valid_p[:C, :M] = valid
    got = np.asarray(katana_greedy_assign(
        jnp.asarray(cost_p), jnp.asarray(valid_p), gate=gate,
        rounds=min(C + pad_c, M + pad_m)))
    np.testing.assert_array_equal(got[:C], ref)
    assert (got[C:] == -1).all()


# ---------------------------------------------------------------------------
# Frame-level equivalence: fused vs einsum across full lifecycles.
# ---------------------------------------------------------------------------

def _assert_frames_equal(rf, re, atol):
    np.testing.assert_array_equal(np.asarray(rf.assoc), np.asarray(re.assoc))
    np.testing.assert_array_equal(np.asarray(rf.unassigned),
                                  np.asarray(re.unassigned))
    np.testing.assert_array_equal(np.asarray(rf.confirmed),
                                  np.asarray(re.confirmed))
    np.testing.assert_array_equal(np.asarray(rf.bank.track_id),
                                  np.asarray(re.bank.track_id))
    np.testing.assert_array_equal(np.asarray(rf.bank.hits),
                                  np.asarray(re.bank.hits))
    np.testing.assert_allclose(np.asarray(rf.bank.x), np.asarray(re.bank.x),
                               atol=atol)
    np.testing.assert_allclose(np.asarray(rf.bank.P), np.asarray(re.bank.P),
                               atol=atol)


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_fused_frame_matches_einsum_lifecycle(kind):
    """100-frame clutter + birth/death scene: spawn, coast, prune all
    happen, and the fused dispatch stays in lockstep with the einsum
    oracle — identical assoc and ids every frame, float32-close
    states."""
    model = get_filter(kind)
    assert frame_kernel_supported(model)
    scene = SceneConfig(T=100, max_targets=4, max_meas=16, clutter_rate=0.5,
                        death_rate=0.02)
    z, valid, _ = mot_scene(model, scene, seed=11)
    step_f = jax.jit(lambda b, z, v: frame_step(model, CFG, b, z, v))
    step_e = jax.jit(lambda b, z, v: frame_step(model, CFG_EINSUM, b, z, v))
    bf = bank_lib.init_bank(model, CFG.capacity)
    be = bank_lib.init_bank(model, CFG.capacity)
    for t in range(scene.T):
        zt, vt = jnp.asarray(z[t], jnp.float32), jnp.asarray(valid[t])
        rf = step_f(bf, zt, vt)
        re = step_e(be, zt, vt)
        _assert_frames_equal(rf, re, atol=1e-4)
        bf, be = rf.bank, re.bank
    assert int(rf.bank.next_id) == int(re.bank.next_id)


def test_fused_imm_frame_matches_einsum_lifecycle():
    """The multi-model twin of the lifecycle test: the one-dispatch IMM
    frame (mixing, weighted gate, K updates, mode posterior, combined
    estimate in-kernel) tracks the einsum ``imm_frame_step`` across a
    100-frame lifecycle — identical assoc/ids, close mu and combined
    states."""
    imm = make_imm()
    cv9 = get_filter("cv9")
    scene = SceneConfig(T=100, max_targets=4, max_meas=16, clutter_rate=0.5,
                        death_rate=0.02)
    z, valid, _ = mot_scene(cv9, scene, seed=17)
    step_f = jax.jit(lambda b, z, v: imm_frame_step(imm, CFG, b, z, v))
    step_e = jax.jit(lambda b, z, v: imm_frame_step(imm, CFG_EINSUM, b, z, v))
    bf = bank_lib.init_imm_bank(imm, CFG.capacity)
    be = bank_lib.init_imm_bank(imm, CFG.capacity)
    for t in range(scene.T):
        zt, vt = jnp.asarray(z[t], jnp.float32), jnp.asarray(valid[t])
        rf = step_f(bf, zt, vt)
        re = step_e(be, zt, vt)
        _assert_frames_equal(rf, re, atol=5e-4)
        np.testing.assert_allclose(np.asarray(rf.mode_probs),
                                   np.asarray(re.mode_probs), atol=5e-4)
        np.testing.assert_allclose(np.asarray(rf.x_est),
                                   np.asarray(re.x_est), atol=5e-4)
        bf, be = rf.bank, re.bank


def test_fused_imm_k1_reduces_to_fused_frame():
    """The degenerate K=1 IMM frame emits exactly the single-model
    frame kernel's op stream (nonlinear EKF member included): bank
    states match BITWISE, and mu stays exactly 1."""
    model = get_filter("ekf")
    imm1 = as_imm(model)
    assert frame_kernel_supported(imm1)
    scene = SceneConfig(T=25, max_targets=3, max_meas=16, clutter_rate=0.4,
                        death_rate=0.0)
    z, valid, _ = mot_scene(model, scene, seed=3)
    step_i = jax.jit(lambda b, z, v: imm_frame_step(imm1, CFG, b, z, v))
    step_s = jax.jit(lambda b, z, v: frame_step(model, CFG, b, z, v))
    bi = bank_lib.init_imm_bank(imm1, CFG.capacity)
    bs = bank_lib.init_bank(model, CFG.capacity)
    for t in range(scene.T):
        zt, vt = jnp.asarray(z[t], jnp.float32), jnp.asarray(valid[t])
        ri = step_i(bi, zt, vt)
        rs = step_s(bs, zt, vt)
        np.testing.assert_array_equal(np.asarray(ri.assoc),
                                      np.asarray(rs.assoc))
        np.testing.assert_array_equal(np.asarray(ri.bank.x[0]),
                                      np.asarray(rs.bank.x))
        np.testing.assert_array_equal(np.asarray(ri.bank.P[0]),
                                      np.asarray(rs.bank.P))
        np.testing.assert_array_equal(np.asarray(ri.bank.mu),
                                      np.ones_like(np.asarray(ri.bank.mu)))
        bi, bs = ri.bank, rs.bank


def test_fused_frame_falls_back_for_general_H():
    """A non-selector measurement matrix is outside the kernel contract:
    ``fused_frame=True`` must silently take the einsum route (and agree
    with the explicit einsum config), not crash."""
    model = get_filter("lkf")
    H = np.asarray(model.H).copy()
    H[0, 3] = 0.5  # position row also reads a velocity component
    general = dataclasses.replace(model, H=H)
    assert not frame_kernel_supported(general)
    rng = np.random.default_rng(0)
    bank = bank_lib.init_bank(general, CFG.capacity)
    z = jnp.asarray(rng.normal(size=(CFG.max_meas, general.m)), jnp.float32)
    v = jnp.asarray(rng.random(CFG.max_meas) < 0.5)
    rf = frame_step(general, CFG, bank, z, v)
    re = frame_step(general, CFG_EINSUM, bank, z, v)
    np.testing.assert_array_equal(np.asarray(rf.assoc), np.asarray(re.assoc))
    np.testing.assert_array_equal(np.asarray(rf.bank.x),
                                  np.asarray(re.bank.x))


def test_fused_frame_under_sharded_engine():
    """The fused frame serves the multi-sensor fleet: a fused-config
    ``ShardedBankEngine`` stays in lockstep (identical assoc/ids,
    close states) with an einsum-config fleet over a multi-frame run,
    sensors disagreeing about spawn/coast as they please."""
    from repro.serving.engine import ShardedBankEngine

    imm = make_imm()
    cfg_f = TrackerConfig(capacity=16, max_meas=8)
    cfg_e = dataclasses.replace(cfg_f, fused_frame=False)
    S = 3
    eng_f = ShardedBankEngine(imm, S, cfg_f)
    eng_e = ShardedBankEngine(imm, S, cfg_e)
    rng = np.random.default_rng(23)
    pos = rng.normal(size=(S, 2, imm.m)) * 3
    for t in range(12):
        pos = pos + 0.05
        z = np.zeros((S, cfg_f.max_meas, imm.m), np.float32)
        v = np.zeros((S, cfg_f.max_meas), bool)
        k = 2 if t % 5 else 1  # sensors drop a detection now and then
        z[:, :k] = (pos + rng.normal(size=pos.shape) * 0.05)[:, :k]
        v[:, :k] = True
        rf, re = eng_f.frame(z, v), eng_e.frame(z, v)
        np.testing.assert_array_equal(np.asarray(rf.assoc),
                                      np.asarray(re.assoc))
        np.testing.assert_array_equal(np.asarray(rf.bank.track_id),
                                      np.asarray(re.bank.track_id))
        np.testing.assert_allclose(np.asarray(rf.x_est),
                                   np.asarray(re.x_est), atol=5e-4)
