"""Streaming front end units + properties (serving/stream.py).

Property layer (via tests/_hypothesis_compat.py, so it runs with or
without hypothesis installed):

  * the slot allocator NEVER hands two tenants the same (shard, lane)
    and NEVER exceeds the live lane pool, across any interleaving of
    acquire/release/drop_shard;
  * track-id namespaces are never reissued;
  * the degradation ladder is monotone: more load never yields a
    better service tier.

Unit layer: admission decisions (duplicates, drop-oldest, queue-full,
overload reject, deadline expiry), the circuit breaker state machine,
cross-tenant isolation of the fused dispatch, idle-lane freezing, the
NaN guard coasting corrupt payloads, and checkpoint cadence.
"""
import numpy as np
import pytest

from repro.core.filters import make_cv_lkf, make_imm
from repro.core.tracker import TrackerConfig, frame_step
from repro.serving.stream import (Admission, CircuitBreaker,
                                  DegradationLadder, NS_STRIDE,
                                  ServiceTier, SlotAllocator,
                                  StreamConfig, StreamFrontEnd)

from _hypothesis_compat import given, settings, st


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


MODEL = make_imm()
CV = make_cv_lkf()
TRACKER = TrackerConfig(capacity=8, max_meas=4)


def make_front(tmp_path, clk=None, **kw):
    kw.setdefault("n_shards", 2)
    kw.setdefault("lanes_per_shard", 2)
    kw.setdefault("queue_depth", 3)
    kw.setdefault("checkpoint_every", 4)
    return StreamFrontEnd(MODEL, StreamConfig(**kw), TRACKER,
                          ckpt_dir=str(tmp_path),
                          clock=clk or FakeClock())


def scene(seed, k=2, m=3):
    return np.random.default_rng(seed).normal(
        scale=5.0, size=(k, m)).astype(np.float32)


# ---------------------------------------------------- allocator properties
@settings(max_examples=25)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_allocator_slots_unique_and_bounded(n_shards, lanes, seed):
    """Random interleavings of acquire/release/drop_shard: no slot is
    ever shared, the pool never over-allocates, namespaces are never
    reissued."""
    rng = np.random.default_rng(seed)
    alloc = SlotAllocator(n_shards, lanes)
    live = {}
    seen_ns = set()
    dropped = set()
    for i in range(60):
        op = rng.integers(0, 10)
        if op < 5:  # acquire
            t = f"t{i}"
            loc = alloc.acquire(t)
            if loc is not None:
                assert loc not in live.values(), "slot double-booked"
                assert loc[0] not in dropped, "dead shard's lane reused"
                assert loc[1] < lanes
                live[t] = loc
                ns = alloc.next_namespace()
                assert ns not in seen_ns, "namespace reissued"
                assert ns % NS_STRIDE == 0
                seen_ns.add(ns)
            else:
                # full is the only reason to refuse
                free_live = sum(
                    1 for s in range(n_shards) if s not in dropped
                ) * lanes - len(live)
                assert free_live == 0
        elif op < 8 and live:  # release
            t = list(live)[int(rng.integers(0, len(live)))]
            alloc.release(t)
            del live[t]
        elif op == 9 and len(dropped) < n_shards - 1:  # drop a shard
            s = int(rng.integers(0, n_shards))
            if s not in dropped:
                for t in alloc.tenants_on(s):
                    alloc.release(t)
                    del live[t]
                alloc.drop_shard(s)
                dropped.add(s)
        assert len(set(alloc.where.values())) == len(alloc.where)
        assert len(live) <= (n_shards - len(dropped)) * lanes


def test_allocator_rejects_double_acquire():
    alloc = SlotAllocator(1, 2)
    alloc.acquire("a")
    with pytest.raises(ValueError, match="already holds"):
        alloc.acquire("a")


def test_allocator_balances_across_shards():
    alloc = SlotAllocator(2, 2)
    shards = [alloc.acquire(f"t{i}")[0] for i in range(4)]
    assert sorted(shards[:2]) == [0, 1]  # spread before packing


# ------------------------------------------------------- ladder properties
@settings(max_examples=25)
@given(st.integers(1, 999), st.integers(1, 999), st.integers(0, 1000))
def test_ladder_monotone_in_load(a_millis, b_millis, n):
    """For any valid thresholds and any pair of loads, more load never
    yields a lower (better) tier."""
    lo, hi = sorted((a_millis / 1000.0, b_millis / 1000.0))
    ladder = DegradationLadder(lo, (lo + hi) / 2.0, hi)
    loads = np.linspace(0.0, 1.5, 61)
    tiers = [ladder.tier_for(l) for l in loads]
    assert all(t2 >= t1 for t1, t2 in zip(tiers, tiers[1:]))
    # and the single sampled pair, for the shrunk counterexample
    l1 = n / 1000.0
    assert ladder.tier_for(l1 + 0.25) >= ladder.tier_for(l1)


def test_ladder_hits_every_tier():
    ladder = DegradationLadder(0.25, 0.5, 0.75)
    assert ladder.tier_for(0.0) == ServiceTier.FULL
    assert ladder.tier_for(0.3) == ServiceTier.WIDE_GATE
    assert ladder.tier_for(0.6) == ServiceTier.COAST_ONLY
    assert ladder.tier_for(0.9) == ServiceTier.REJECT


def test_config_rejects_unsorted_thresholds():
    with pytest.raises(ValueError, match="sorted"):
        StreamConfig(degrade_at=0.8, coast_at=0.5, reject_at=0.9)


# --------------------------------------------------------- circuit breaker
def test_breaker_state_machine():
    clk = FakeClock()
    br = CircuitBreaker(failure_threshold=2, cooldown_s=5.0, clock=clk)
    assert br.allow() and br.state == br.CLOSED
    br.record_failure()
    assert br.allow()  # one failure is not a trip
    br.record_failure()
    assert not br.allow() and br.state == br.OPEN
    clk.advance(5.0)
    assert br.state == br.HALF_OPEN and br.allow()  # probe allowed
    br.record_failure()  # probe failed: re-open with fresh cooldown
    assert not br.allow()
    clk.advance(5.0)
    br.record_success()  # probe succeeded
    assert br.state == br.CLOSED and br.failures == 0


# --------------------------------------------------------------- admission
class TestAdmission:
    def test_duplicate_and_stale_seqs_dropped(self, tmp_path):
        fe = make_front(tmp_path)
        fe.attach("a")
        assert fe.submit("a", scene(0)) == Admission.ACCEPTED
        assert fe.submit("a", scene(0), seq=0) == Admission.DUPLICATE
        fe.pump()
        assert fe.submit("a", scene(1)) == Admission.ACCEPTED  # seq 1
        assert fe.submit("a", scene(0), seq=0) == Admission.DUPLICATE
        assert fe.stats.duplicates == 2

    def test_drop_oldest_replaces(self, tmp_path):
        fe = make_front(tmp_path, queue_depth=2, degrade_at=1.5,
                        coast_at=1.75, reject_at=2.0)
        fe.attach("a")
        fe.submit("a", scene(0))
        fe.submit("a", scene(1))
        assert fe.submit("a", scene(2)) == Admission.REPLACED_OLDEST
        assert [r.seq for r in fe.tenants["a"].queue] == [1, 2]

    def test_queue_full_rejects_without_drop_oldest(self, tmp_path):
        fe = make_front(tmp_path, queue_depth=2, drop_oldest=False,
                        degrade_at=1.5, coast_at=2.0, reject_at=3.0)
        fe.attach("a")
        fe.submit("a", scene(0))
        fe.submit("a", scene(1))
        assert fe.submit("a", scene(2)) == Admission.REJECTED_QUEUE_FULL
        assert [r.seq for r in fe.tenants["a"].queue] == [0, 1]

    def test_overload_rejects_at_ladder_top(self, tmp_path):
        fe = make_front(tmp_path, queue_depth=4, degrade_at=0.2,
                        coast_at=0.3, reject_at=0.5)
        fe.attach("a")
        fe.submit("a", scene(0))
        fe.submit("a", scene(1))  # load now 0.5 -> REJECT
        assert fe.effective_tier() == ServiceTier.REJECT
        assert fe.submit("a", scene(2)) == Admission.REJECTED_OVERLOAD

    def test_attach_beyond_capacity_rejected(self, tmp_path):
        fe = make_front(tmp_path, n_shards=1, lanes_per_shard=2)
        assert fe.attach("a") == Admission.ACCEPTED
        assert fe.attach("b") == Admission.ACCEPTED
        assert fe.attach("c") == Admission.REJECTED_NO_CAPACITY
        fe.detach("a")
        assert fe.attach("c") == Admission.ACCEPTED

    def test_expired_deadline_shed_before_dispatch(self, tmp_path):
        clk = FakeClock()
        fe = make_front(tmp_path, clk=clk)
        fe.attach("a")
        fe.submit("a", scene(0), deadline=clk() + 0.05)
        clk.advance(0.1)
        ups = fe.pump()
        assert "a" not in ups
        assert fe.stats.expired == 1 and fe.stats.applied == 0


# --------------------------------------------------------------- the pump
class TestPump:
    def test_tenant_isolation_identical_scenes(self, tmp_path):
        """Two tenants fed the SAME measurements produce bitwise the
        same independent streams — the fused dispatch leaks nothing
        across lanes (the no-shared-C-slot property, observed)."""
        fe = make_front(tmp_path, n_shards=1, lanes_per_shard=2)
        fe.attach("a")
        fe.attach("b")
        for f in range(6):
            z = scene(f)
            fe.submit("a", z)
            fe.submit("b", z)
            ups = fe.pump()
            sa, sb = ups["a"].snapshots, ups["b"].snapshots
            assert len(sa) == len(sb)
            for ta, tb in zip(sa, sb):
                np.testing.assert_array_equal(ta.state, tb.state)
                # same local id, disjoint global namespaces
                assert ta.track_id % NS_STRIDE == tb.track_id % NS_STRIDE
                assert ta.track_id // NS_STRIDE != tb.track_id // NS_STRIDE

    def test_idle_lane_frozen_not_coasted(self, tmp_path):
        """A tenant with nothing queued must not have its tracks aged
        by other tenants' pumps: its stream is frame-indexed."""
        fe = make_front(tmp_path, n_shards=1, lanes_per_shard=2)
        fe.attach("a")
        fe.attach("b")
        for f in range(4):  # a confirms some tracks
            fe.submit("a", scene(f))
            fe.submit("b", scene(f + 100))
            fe.pump()
        lane_before = np.asarray(
            fe.shards[0].banks.age)[..., fe.tenants["a"].lane, :]
        for f in range(3):  # only b pumps
            fe.submit("b", scene(f + 200))
            fe.pump()
        lane_after = np.asarray(
            fe.shards[0].banks.age)[..., fe.tenants["a"].lane, :]
        np.testing.assert_array_equal(lane_before, lane_after)

    def test_empty_frame_coasts(self, tmp_path):
        fe = make_front(tmp_path)
        fe.attach("a")
        for f in range(4):
            fe.submit("a", scene(f))
            fe.pump()
        fe.submit("a", np.zeros((0, 3), np.float32))  # dark sensor
        ups = fe.pump()
        assert ups["a"].kind == "coast"
        assert fe.stats.coasted == 1

    def test_nan_payload_coasts_instead_of_poisoning(self, tmp_path):
        fe = make_front(tmp_path)
        fe.attach("a")
        for f in range(3):
            fe.submit("a", scene(f))
            fe.pump()
        bad = scene(3)
        bad[0, 0] = np.nan
        bad[1, 1] = np.inf
        fe.submit("a", bad)
        ups = fe.pump()
        lane = fe.tenants["a"].lane
        x = np.asarray(fe.shards[fe.tenants["a"].shard].banks.x)
        assert np.isfinite(x[:, lane]).all(), "NaN reached the bank"
        assert ups["a"].kind == "served"

    def test_ladder_sheds_measurements_under_load(self, tmp_path):
        fe = make_front(tmp_path, queue_depth=4, degrade_at=0.1,
                        coast_at=0.4, reject_at=0.9)
        fe.attach("a")
        for f in range(3):
            fe.submit("a", scene(f))
        assert fe.effective_tier() == ServiceTier.COAST_ONLY
        ups = fe.pump()
        assert ups["a"].kind == "shed"
        assert fe.stats.shed == 1

    def test_checkpoint_cadence(self, tmp_path):
        fe = make_front(tmp_path, checkpoint_every=3)
        fe.attach("a")
        assert fe.stats.checkpoints == 1  # the frame-0 baseline
        for f in range(7):
            fe.submit("a", scene(f))
            fe.pump()
        # baselines at frames 3 and 6 on top of frame 0
        assert fe.stats.checkpoints == 3
        assert len(fe.tenants["a"].wal) == 1  # frame 7 since last snap

    def test_single_model_front_end(self, tmp_path):
        fe = StreamFrontEnd(CV, StreamConfig(n_shards=1,
                                             lanes_per_shard=2),
                            TRACKER, ckpt_dir=str(tmp_path),
                            clock=FakeClock())
        fe.attach("a")
        for f in range(4):
            fe.submit("a", scene(f))
            ups = fe.pump()
        assert fe.stats.served == 4
        for snap in ups["a"].snapshots:
            assert snap.mode_probs is None


# ------------------------------------------------------- wide-gate variant
def test_wide_gate_tier_uses_scaled_config(tmp_path):
    fe = make_front(tmp_path, queue_depth=4, degrade_at=0.2,
                    coast_at=0.9, reject_at=0.95)
    fe.attach("a")
    fe.submit("a", scene(0))
    fe.submit("a", scene(1))  # load 0.5 -> WIDE_GATE
    assert fe.effective_tier() == ServiceTier.WIDE_GATE
    ups = fe.pump()
    assert ups["a"].tier == ServiceTier.WIDE_GATE
    wide = fe._tier_cfg[ServiceTier.WIDE_GATE]
    assert wide.gate_scale == pytest.approx(
        TRACKER.gate_scale * fe.cfg.wide_gate_scale)
    # the base config is untouched — tiers are separate static configs
    assert fe._tier_cfg[ServiceTier.FULL].gate_scale == TRACKER.gate_scale
