"""Optional-hypothesis shim for the test suite.

When ``hypothesis`` is installed the real ``given``/``settings``/``st``
are re-exported unchanged. When it is absent (the minimal container
image), the property tests degrade to fixed-seed parametrized cases:
``given`` samples ``max_examples`` tuples from the strategies with a
deterministic per-test rng and applies ``pytest.mark.parametrize``.
Coverage shrinks (no shrinking, no adaptive search) but every property
still runs — the suite never fails to *collect*.
"""
from __future__ import annotations

import inspect
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo = int(min_value)
            self.hi = int(max_value)

        def sample(self, rng: np.random.Generator) -> int:
            return int(rng.integers(self.lo, self.hi + 1))

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n_ex = getattr(fn, "_compat_max_examples", 10)
            # deterministic per-test seed so failures reproduce
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            names = list(inspect.signature(fn).parameters)[: len(strategies)]
            cases = [
                tuple(s.sample(rng) for s in strategies) for _ in range(n_ex)
            ]
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
