"""Sharded multi-sensor IMM engine (serving/engine.ShardedBankEngine).

The serving tentpole: ``imm_frame_step`` vmapped over the sensor axis,
the (K, S, C, n) IMM bank shard_mapped over the mesh data axes, and a
sharded fused replay. Everything here is equivalence against the
unsharded per-sensor oracles:

  * the vmapped fleet == a python loop of single-sensor frame steps
    (runs on any device count — the always-on tier-1 leg);
  * the shard_mapped fleet == the vmapped fleet, bitwise (needs >= 4
    local devices — CI runs this under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
  * K=1 reduces to the single-model sharded path;
  * ``replay`` == per-sensor ``replay_imm_bank`` on coasting-masked
    streams, one fused dispatch per track batch per shard;
  * multi-sensor lifecycle: sensors that disagree (one spawns while
    another coasts/prunes) keep their shared-across-hypotheses track
    ids exactly in lockstep with the unsharded oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import bank as bank_lib
from repro.core.bank import IMMBankState, init_imm_bank, replay_imm_bank
from repro.core.filters import as_imm, make_cv9_lkf, make_imm
from repro.core.tracker import TrackerConfig, frame_step, imm_frame_step
from repro.serving.engine import ShardedBankEngine

CFG = TrackerConfig(capacity=8, max_meas=4)


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 local devices (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    return make_mesh((4,), ("data",))


def _fleet_scene(S, T, cfg=CFG, seed=0, targets=2, drop=()):
    """(T, S, max_meas, m) measurement streams: `targets` slow walkers
    per sensor; ``drop`` lists (sensor, first_frame) pairs after which
    that sensor goes dark (its tracks coast, then prune)."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(S, targets, 3)) * 3
    z = np.zeros((T, S, cfg.max_meas, 3), np.float32)
    v = np.zeros((T, S, cfg.max_meas), bool)
    for t in range(T):
        pos = pos + 0.05
        z[t, :, :targets] = pos + rng.normal(size=pos.shape) * 0.05
        v[t, :, :targets] = True
        for s, t0 in drop:
            if t >= t0:
                v[t, s] = False
    return z, v


def _per_sensor_oracle(model, z, v, cfg=CFG):
    """Unsharded reference: one imm_frame_step / frame_step per sensor
    per frame, banks never stacked. Yields the per-frame results."""
    is_imm = hasattr(model, "models")
    S = z.shape[1]
    init = bank_lib.init_imm_bank if is_imm else bank_lib.init_bank
    step = imm_frame_step if is_imm else frame_step
    banks = [init(model, cfg.capacity) for _ in range(S)]
    for t in range(z.shape[0]):
        res = []
        for s in range(S):
            r = step(model, cfg, banks[s], jnp.asarray(z[t, s]),
                     jnp.asarray(v[t, s]))
            banks[s] = r.bank
            res.append(r)
        yield res


def _check_fleet_matches_oracle(engine, model, z, v):
    for t, oracle in enumerate(_per_sensor_oracle(model, z, v, engine.cfg)):
        res = engine.frame(z[t], v[t])
        for s, r in enumerate(oracle):
            np.testing.assert_array_equal(np.asarray(res.assoc)[s],
                                          np.asarray(r.assoc))
            np.testing.assert_array_equal(np.asarray(res.confirmed)[s],
                                          np.asarray(r.confirmed))
            np.testing.assert_array_equal(np.asarray(res.bank.track_id)[s],
                                          np.asarray(r.bank.track_id))
            if engine.is_imm:
                np.testing.assert_allclose(np.asarray(res.x_est)[s],
                                           np.asarray(r.x_est),
                                           atol=1e-5, rtol=1e-5)
                np.testing.assert_allclose(np.asarray(res.mode_probs)[s],
                                           np.asarray(r.bank.mu),
                                           atol=1e-5)
            else:
                np.testing.assert_allclose(np.asarray(res.bank.x)[s],
                                           np.asarray(r.bank.x),
                                           atol=1e-5, rtol=1e-5)


# ------------------------------------------------- vmapped fleet (any host)
def test_vmapped_imm_fleet_matches_per_sensor_oracle():
    """No mesh: the vmapped multi-sensor IMM step is frame-by-frame
    identical to S independent single-sensor imm_frame_step loops."""
    imm = make_imm()
    z, v = _fleet_scene(S=3, T=10, seed=0)
    eng = ShardedBankEngine(imm, 3, CFG)
    assert eng.is_imm
    # track ids are per-SLOT (shared across the K hypotheses): (S, C)
    assert np.asarray(eng.banks.track_id).shape == (3, CFG.capacity)
    assert np.asarray(eng.banks.x).shape == (imm.K, 3, CFG.capacity, imm.n)
    _check_fleet_matches_oracle(eng, imm, z, v)


def test_vmapped_fleet_snapshots_carry_mode_probs():
    imm = make_imm()
    z, v = _fleet_scene(S=2, T=8, seed=3)
    eng = ShardedBankEngine(imm, 2, CFG)
    for t in range(z.shape[0]):
        res = eng.frame(z[t], v[t])
    snaps = eng.snapshots(res)
    assert len(snaps) == 2 and all(len(s) == 2 for s in snaps)
    for s in snaps:
        for snap in s:
            assert snap.state.shape == (imm.n,)
            np.testing.assert_allclose(snap.mode_probs.sum(), 1.0, atol=1e-5)


# ------------------------------------------------ sharded fleet (>=4 devs)
def test_sharded_imm_engine_matches_unsharded(mesh):
    """shard_map over the mesh data axis changes NOTHING: every frame's
    bank state, associations, ids and combined estimates are bitwise
    equal to the unsharded vmapped fleet (sensors are independent, each
    shard runs the identical per-sensor program)."""
    imm = make_imm()
    S, T = 8, 10
    z, v = _fleet_scene(S=S, T=T, seed=1)
    sharded = ShardedBankEngine(imm, S, CFG, mesh=mesh)
    local = ShardedBankEngine(imm, S, CFG)
    for t in range(T):
        rs = sharded.frame(z[t], v[t])
        rl = local.frame(z[t], v[t])
        np.testing.assert_array_equal(np.asarray(rs.bank.x),
                                      np.asarray(rl.bank.x))
        np.testing.assert_array_equal(np.asarray(rs.bank.mu),
                                      np.asarray(rl.bank.mu))
        np.testing.assert_array_equal(np.asarray(rs.bank.track_id),
                                      np.asarray(rl.bank.track_id))
        np.testing.assert_array_equal(np.asarray(rs.x_est),
                                      np.asarray(rl.x_est))


def test_sharded_imm_engine_matches_per_sensor_oracle(mesh):
    """End-to-end acceptance: the sharded fleet against the unsharded
    per-sensor imm_frame_step oracle (allclose at fp32)."""
    imm = make_imm()
    z, v = _fleet_scene(S=8, T=8, seed=2)
    eng = ShardedBankEngine(imm, 8, CFG, mesh=mesh)
    _check_fleet_matches_oracle(eng, imm, z, v)


def test_sharded_k1_reduces_to_single_model_path(mesh):
    """as_imm(cv9) with K=1 on the sharded engine == the plain
    single-model sharded path: same ids, same states (the IMM mixing /
    combination collapse to identities at K=1)."""
    cv9 = make_cv9_lkf()
    S, T = 4, 8
    z, v = _fleet_scene(S=S, T=T, seed=4)
    plain = ShardedBankEngine(cv9, S, CFG, mesh=mesh)
    k1 = ShardedBankEngine(as_imm(cv9), S, CFG, mesh=mesh)
    assert not plain.is_imm and k1.is_imm
    for t in range(T):
        rp = plain.frame(z[t], v[t])
        rk = k1.frame(z[t], v[t])
        np.testing.assert_array_equal(np.asarray(rp.bank.track_id),
                                      np.asarray(rk.bank.track_id))
        np.testing.assert_array_equal(np.asarray(rp.confirmed),
                                      np.asarray(rk.confirmed))
        np.testing.assert_allclose(np.asarray(rk.x_est),
                                   np.asarray(rp.bank.x),
                                   atol=1e-6, rtol=1e-6)
    assert rp.mode_probs is None
    np.testing.assert_array_equal(np.asarray(rk.mode_probs),
                                  np.ones((S, CFG.capacity, 1), np.float32))


# ----------------------------------------------------------- fused replay
def _slice_bank(banks, s):
    """Sensor s's single-sensor IMMBankState out of the stacked fleet."""
    return IMMBankState(
        x=jnp.asarray(np.asarray(banks.x)[:, s]),
        P=jnp.asarray(np.asarray(banks.P)[:, s]),
        mu=jnp.asarray(np.asarray(banks.mu)[s]),
        active=jnp.asarray(np.asarray(banks.active)[s]),
        hits=jnp.asarray(np.asarray(banks.hits)[s]),
        misses=jnp.asarray(np.asarray(banks.misses)[s]),
        age=jnp.asarray(np.asarray(banks.age)[s]),
        track_id=jnp.asarray(np.asarray(banks.track_id)[s]),
        next_id=jnp.asarray(np.asarray(banks.next_id)[s]))


def test_sharded_replay_matches_replay_imm_bank(mesh):
    """engine.replay routes through katana_imm_sequence (one dispatch
    per shard, local sensors flattened onto the track axis) and matches
    per-sensor replay_imm_bank frame-by-frame on a coasting-masked
    stream, seeded from the live mode-conditioned banks."""
    imm = make_imm()
    S, T, T2 = 8, 6, 12
    z, v = _fleet_scene(S=S, T=T, seed=5)
    eng = ShardedBankEngine(imm, S, CFG, mesh=mesh)
    for t in range(T):
        eng.frame(z[t], v[t])
    rng = np.random.default_rng(7)
    zs = (rng.normal(size=(T2, S, CFG.capacity, imm.m)) * 0.5
          ).astype(np.float32)
    valid = rng.random((T2, S, CFG.capacity)) > 0.3
    valid[3] = False  # a whole coasted frame, fleet-wide
    out = eng.replay(zs, valid)
    assert out.shape == (T2, S, CFG.capacity, imm.n)
    assert np.isfinite(out).all()
    for s in range(S):
        want = np.asarray(replay_imm_bank(
            imm, _slice_bank(eng.banks, s), jnp.asarray(zs[:, s]),
            valid=jnp.asarray(valid[:, s])))
        np.testing.assert_allclose(out[:, s], want, atol=1e-6, rtol=1e-6)
    assert eng.stats.replay_frames == T2
    assert eng.stats.frames == T  # replay never dilutes serving fps


def test_vmapped_replay_matches_replay_imm_bank():
    """Same replay contract without a mesh (the always-on leg)."""
    imm = make_imm()
    S, T2 = 2, 10
    z, v = _fleet_scene(S=S, T=4, seed=6)
    eng = ShardedBankEngine(imm, S, CFG)
    for t in range(4):
        eng.frame(z[t], v[t])
    rng = np.random.default_rng(8)
    zs = (rng.normal(size=(T2, S, CFG.capacity, imm.m)) * 0.5
          ).astype(np.float32)
    valid = rng.random((T2, S, CFG.capacity)) > 0.4
    out = eng.replay(zs, valid)
    for s in range(S):
        want = np.asarray(replay_imm_bank(
            imm, _slice_bank(eng.banks, s), jnp.asarray(zs[:, s]),
            valid=jnp.asarray(valid[:, s])))
        np.testing.assert_allclose(out[:, s], want, atol=1e-6, rtol=1e-6)


# ----------------------------------------------- multi-sensor lifecycle
def _disagreeing_scene(S=4, T=14):
    """Sensor 1 goes dark at frame 4 (coast -> prune), sensor 2 starts
    dark and first detects at frame 6 (late spawn); the rest track
    normally — maximal lifecycle disagreement across the fleet."""
    z, v = _fleet_scene(S=S, T=T, seed=9, drop=((1, 4),))
    v[:6, 2] = False
    return z, v


@pytest.mark.parametrize("use_mesh", [False, True])
def test_multi_sensor_lifecycle_disagreement(use_mesh, request):
    """Spawn/prune interplay when sensors disagree: one sensor spawns
    while another coasts. Per-sensor id counters stay independent,
    pruned slots free up only on the dark sensor, and the
    shared-across-hypotheses track ids never diverge from the unsharded
    oracle on any shard, any frame."""
    mesh = request.getfixturevalue("mesh") if use_mesh else None
    imm = make_imm()
    cfg = TrackerConfig(capacity=8, max_meas=4, max_misses=3)
    S, T = 4, 14
    z, v = _disagreeing_scene(S=S, T=T)
    eng = ShardedBankEngine(imm, S, cfg, mesh=mesh)
    oracle = _per_sensor_oracle(imm, z, v, cfg)
    for t, per_sensor in enumerate(oracle):
        res = eng.frame(z[t], v[t])
        ids = np.asarray(res.bank.track_id)
        for s, r in enumerate(per_sensor):
            np.testing.assert_array_equal(ids[s], np.asarray(r.bank.track_id))
            np.testing.assert_array_equal(np.asarray(res.bank.active)[s],
                                          np.asarray(r.bank.active))
        # active ids stay unique per sensor (never reused while live)
        act = np.asarray(res.bank.active)
        for s in range(S):
            live = ids[s][act[s]].tolist()
            assert len(live) == len(set(live))
    bank = eng.banks
    active = np.asarray(bank.active)
    # sensor 1 coasted past max_misses: everything pruned
    assert not active[1].any()
    # sensor 2 spawned late but did spawn; sensors 0/3 tracked through
    assert active[2].sum() == 2
    assert active[0].sum() == 2 and active[3].sum() == 2
    # per-sensor id counters advanced independently (no cross-sensor
    # coupling through the stacked next_id)
    next_ids = np.asarray(bank.next_id)
    assert next_ids.shape == (S,)
    assert next_ids[0] == 2 and next_ids[2] == 2
    # mode probabilities on live tracks remain distributions
    mu = np.asarray(bank.mu)
    np.testing.assert_allclose(mu[active].sum(-1), 1.0, atol=1e-5)
