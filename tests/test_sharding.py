"""Sharding-rule units + miniature dry-runs (4x2 mesh, reduced archs):
the same lower+compile+census pipeline as launch/dryrun.py, sized for
CI. The production-mesh (256/512-chip) runs live in results/dryrun/."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import RunConfig, get_config, get_shape, reduced
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_decode_step, make_train_step
from repro.models import model as model_lib
from repro.optim import adamw
from repro.roofline.hlo import collective_census, totals
from repro.sharding.rules import ShardingContext, logical_to_spec, make_context


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 local devices (run under XLA_FLAGS host count)")
    return make_mesh((4, 2), ("data", "model"))


def test_logical_rules_divisibility(mesh):
    ctx = ShardingContext(mesh, ("data",), "model")
    # kv=1 (MQA) must degrade to replication on a 2-way model axis
    spec = logical_to_spec(("embed", "kv", None), (64, 1, 16), ctx)
    assert spec[1] is None
    # divisible dims do shard
    spec = logical_to_spec(("embed", "heads", None), (64, 4, 16), ctx)
    assert spec[1] == "model"


def test_param_spec_covers_all_leaves():
    for arch in ("qwen3-moe-235b-a22b", "jamba-1.5-large-398b",
                 "hubert-xlarge"):
        cfg = reduced(get_config(arch))
        aparams = model_lib.abstract_params(cfg)
        pspec = model_lib.param_spec(cfg)
        jax.tree.map(
            lambda axes, arr: None, pspec, aparams,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))  # structure match


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "mamba2-130m",
                                  "jamba-1.5-large-398b"])
def test_mini_dryrun_train(arch, mesh):
    """Reduced arch, 4x2 mesh: train step lowers, compiles, and has a
    sane collective schedule."""
    cfg = dataclasses.replace(
        reduced(get_config(arch), d_model=64, vocab=128, seq=32),
    )
    run = RunConfig(microbatches=2, remat="selective")
    ctx = make_context(mesh)
    astate = adamw.abstract_train_state(model_lib.abstract_params(cfg))
    sshard = specs_lib.state_shardings(cfg, run, ctx)
    batch = {
        "tokens": jax.ShapeDtypeStruct((2, 8, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((2, 8, 32), jnp.int32),
    }
    bshard = {"tokens": NamedSharding(mesh, P(None, ("data",), None)),
              "labels": NamedSharding(mesh, P(None, ("data",), None))}
    step = make_train_step(cfg, run, ctx)
    compiled = jax.jit(step, in_shardings=(sshard, bshard),
                       out_shardings=(sshard, None)).lower(
        astate, batch).compile()
    ma = compiled.memory_analysis()
    assert ma.argument_size_in_bytes > 0
    cc = totals(collective_census(compiled.as_text()))
    assert cc["count"] > 0  # the step actually communicates


def test_mini_dryrun_decode_seq_sharded_cache(mesh):
    """Decode with the KV cache sharded over seq: compiles and does NOT
    all-gather the full cache (flash-decode merge instead)."""
    import re

    cfg = reduced(get_config("h2o-danube-1.8b"), d_model=64, vocab=128,
                  seq=64)
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention,
                                           sliding_window=None))
    ctx = make_context(mesh)
    shape = dataclasses.replace(get_shape("decode_32k"), seq_len=64,
                                global_batch=4)
    aparams = model_lib.abstract_params(cfg)
    pshard = specs_lib.param_shardings(cfg, ctx)
    acache = specs_lib.cache_specs(cfg, shape)
    cshard = specs_lib.cache_shardings(cfg, shape, ctx)
    batch = {"token": jax.ShapeDtypeStruct((4, 1), jnp.int32),
             "cache_pos": jax.ShapeDtypeStruct((), jnp.int32)}
    bshard = {"token": NamedSharding(mesh, P(("data",), None)),
              "cache_pos": NamedSharding(mesh, P())}
    step = make_decode_step(cfg, ctx)
    compiled = jax.jit(step, in_shardings=(pshard, bshard, cshard),
                       out_shardings=(None, cshard)).lower(
        aparams, batch, acache).compile()
    txt = compiled.as_text()
    # no all-gather may produce a full-cache-sized f32/bf16 tensor
    cache_elems = 4 * 64 * cfg.attention.n_kv_heads * cfg.attention.head_dim
    for line in txt.splitlines():
        m = re.search(r"= (\w+)\[([\d,]+)\][^ ]* all-gather", line)
        if m:
            n = np.prod([int(d) for d in m.group(2).split(",")])
            assert n < cache_elems, f"full-cache gather: {line[:120]}"


def test_batch_shardings_handle_indivisible_batch(mesh):
    """long_500k (B=1) must not shard batch over data axes."""
    cfg = get_config("mamba2-130m")
    shape = get_shape("long_500k")
    ctx = make_context(mesh)
    run = RunConfig()
    bs = specs_lib.batch_shardings(cfg, shape, run, ctx)
    assert bs["token"].spec == P(None, None)


def test_moe_tp2d_matches_gather_and_local(mesh):
    """The decode-optimized 2D expert sharding is numerically identical
    to the gather path and the single-device path."""
    import jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.models.moe import apply_moe, moe_init

    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8, period=1)
    d = 16
    key = jax.random.key(0)
    p = moe_init(key, cfg, d, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 2, d), jnp.float32)

    out_local, aux_local = apply_moe(p, x, cfg, "swiglu", None, "full")
    ctx_g = make_context(mesh, fsdp=True)
    ctx_t = make_context(mesh, fsdp=True, moe_weight_mode="tp2d")
    out_g, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg, "swiglu", ctx_g,
                                              "full"))(p, x)
    out_t, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg, "swiglu", ctx_t,
                                              "full"))(p, x)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_local),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_local),
                               atol=1e-5, rtol=1e-4)


def test_elastic_restore_across_mesh_shapes(mesh, tmp_path):
    """Train 3 steps on a (4,2) mesh, checkpoint, restore onto a (2,4)
    mesh (elastic re-shard), continue training: losses stay finite and
    the restored state is bit-identical before the next step."""
    from repro.checkpoint import ckpt as ckpt_lib
    from repro.data.lm import LMDataPipeline

    cfg = reduced(get_config("granite-moe-1b-a400m"), n_layers=2,
                  d_model=64, vocab=64, seq=16)
    run = RunConfig(microbatches=1, remat="none", learning_rate=1e-3,
                    warmup_steps=2, total_steps=10)
    data = LMDataPipeline(cfg.vocab, 16, 8, seed=3)

    def fit(mesh_shape, state, n_steps, data):
        m = make_mesh(mesh_shape, ("data", "model"))
        ctx = make_context(m)
        sshard = specs_lib.state_shardings(cfg, run, ctx)
        state = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), state, sshard)
        step = jax.jit(make_train_step(cfg, run, ctx))
        for _ in range(n_steps):
            batch = {k: jnp.asarray(v)[0] if v.ndim == 3 else jnp.asarray(v)
                     for k, v in data.next_batch().items()}
            batch = {k: v[None] for k, v in batch.items()}  # mb dim
            state, metrics = step(state, batch)
            assert np.isfinite(float(metrics["loss"]))
        return state

    from repro.models import model as mlib
    from repro.optim import adamw as ad

    params = mlib.init_params(cfg, jax.random.key(0))
    state = ad.init_train_state(params)
    state = fit((4, 2), state, 3, data)
    ckpt_lib.save(str(tmp_path), 3, state, {"data": data.state_dict()})

    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), state)
    restored, extra = ckpt_lib.restore(str(tmp_path), like)
    # bit-identical round trip
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), restored, state)
    # resume on a DIFFERENT mesh shape
    data2 = LMDataPipeline(cfg.vocab, 16, 8, seed=3)
    data2.load_state_dict(extra["data"])
    state2 = fit((2, 4), restored, 2, data2)
    assert int(state2.step) == 5
