"""Pallas kernel validation (interpret mode): shape/dtype sweeps +
hypothesis randomization against the pure-jnp/numpy oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.filters import FilterModel, get_filter
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ops import flash_decode, lse_merge
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.katana_bank.kernel import _emit_small_inv, make_kernel
from repro.kernels.katana_bank.ops import katana_bank, katana_bank_sequence
from repro.kernels.katana_bank.ref import katana_bank_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_naive
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------- katana
@pytest.mark.parametrize("kind", ["lkf", "ekf"])
@pytest.mark.parametrize("N", [1, 7, 200, 300])
def test_katana_bank_matches_ref(kind, N):
    model = get_filter(kind)
    rng = np.random.default_rng(N)
    x = jnp.asarray(rng.normal(size=(N, model.n)), jnp.float32)
    A = rng.normal(size=(N, model.n, model.n)) * 0.3
    P = jnp.asarray(A @ A.transpose(0, 2, 1) + np.eye(model.n), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, model.m)), jnp.float32)
    xk, Pk = katana_bank(model, x, P, z, lane_tile=128)
    xr, Pr = katana_bank_ref(model, x, P, z)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(Pk), np.asarray(Pr),
                               atol=2e-5, rtol=2e-4)


def test_katana_bank_tracks_oracle_over_time():
    """Iterated kernel steps track the float64 oracle (no drift)."""
    from repro.core import ref as oref

    model = get_filter("lkf")
    rng = np.random.default_rng(0)
    N, T = 64, 40
    x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    zs = rng.normal(size=(T, N, model.m)) * 0.5
    want, _, _ = oref.run_batched(model, zs, np.asarray(x), np.asarray(P))
    for t in range(T):
        x, P = katana_bank(model, x, P, jnp.asarray(zs[t], jnp.float32),
                           lane_tile=128)
    np.testing.assert_allclose(np.asarray(x), want[-1], atol=5e-4, rtol=5e-4)


@given(st.integers(1, 400), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_katana_bank_hypothesis(N, seed):
    model = get_filter("ekf")
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, model.n)), jnp.float32)
    A = rng.normal(size=(N, model.n, model.n)) * 0.2
    P = jnp.asarray(A @ A.transpose(0, 2, 1) + np.eye(model.n), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, model.m)), jnp.float32)
    xk, Pk = katana_bank(model, x, P, z, lane_tile=128)
    xr, Pr = katana_bank_ref(model, x, P, z)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               atol=5e-5, rtol=5e-4)


# ----------------------------------------------------- katana fused scan
@pytest.mark.parametrize("kind", ["lkf", "ekf"])
@pytest.mark.parametrize("N", [5, 130])  # both non-multiples of lane_tile
def test_fused_scan_matches_oracle_long_sequence(kind, N):
    """One scan dispatch over T=200 frames tracks the float64 oracle
    (padding lanes exercised: N is never a multiple of the tile)."""
    from repro.core import ref as oref

    model = get_filter(kind)
    rng = np.random.default_rng(N)
    T = 200
    zs = rng.normal(size=(T, N, model.m)) * 0.5
    x0 = np.tile(model.x0, (N, 1)) + rng.normal(size=(N, model.n)) * 0.1
    P0 = np.tile(model.P0, (N, 1, 1))
    want, _, _ = oref.run_batched(model, zs, x0, P0)
    got = katana_bank_sequence(model, jnp.asarray(zs, jnp.float32),
                               jnp.asarray(x0, jnp.float32),
                               jnp.asarray(P0, jnp.float32), lane_tile=128)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_fused_scan_matches_batched_lanes(kind):
    """fused_scan == the batched_lanes einsum stage over a long stream:
    the in-kernel time loop is a pure fusion, not a numerics change."""
    from repro.core.rewrites import run_sequence

    model = get_filter(kind)
    rng = np.random.default_rng(3)
    T, N = 200, 7
    zs = rng.normal(size=(T, N, model.m)) * 0.5
    x0 = np.tile(model.x0, (N, 1)) + rng.normal(size=(N, model.n)) * 0.1
    P0 = np.tile(model.P0, (N, 1, 1))
    lanes = np.asarray(run_sequence(model, "batched_lanes", zs, x0, P0,
                                    symmetrize=True))
    fused = np.asarray(katana_bank_sequence(
        model, jnp.asarray(zs, jnp.float32), jnp.asarray(x0, jnp.float32),
        jnp.asarray(P0, jnp.float32), lane_tile=128))
    np.testing.assert_allclose(fused, lanes, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_fused_scan_equals_per_step_kernel(kind):
    """The scan kernel's final (x, P) == T dispatches of the per-frame
    kernel — same emitted step math, only the dispatch granularity (and
    the HBM traffic) differs."""
    model = get_filter(kind)
    rng = np.random.default_rng(5)
    T, N = 25, 9
    zs = rng.normal(size=(T, N, model.m)).astype(np.float32) * 0.5
    x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    _, (xf, Pf) = katana_bank_sequence(model, jnp.asarray(zs), x, P,
                                       lane_tile=128, return_final=True)
    for t in range(T):
        x, P = katana_bank(model, x, P, jnp.asarray(zs[t]), lane_tile=128)
    np.testing.assert_allclose(np.asarray(xf), np.asarray(x), atol=1e-6)
    np.testing.assert_allclose(np.asarray(Pf), np.asarray(P), atol=1e-6)


def test_fused_scan_time_chunking_is_exact():
    """Long streams split over time_chunk dispatches (VMEM bound on T)
    carry (x, P) between chunks bitwise-identically to one dispatch."""
    model = get_filter("ekf")
    rng = np.random.default_rng(8)
    T, N = 50, 6
    zs = jnp.asarray(rng.normal(size=(T, N, model.m)) * 0.5, jnp.float32)
    x0 = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P0 = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    one, (x1, P1) = katana_bank_sequence(model, zs, x0, P0, lane_tile=128,
                                         return_final=True)
    chk, (x2, P2) = katana_bank_sequence(model, zs, x0, P0, lane_tile=128,
                                         return_final=True, time_chunk=16)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(chk))
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(P1), np.asarray(P2))


@pytest.mark.parametrize("m", [1, 2, 3, 4])
def test_emit_small_inv_matches_numpy(m):
    """The kernel's emitted cofactor/Schur inverse (incl. the 2x2 block
    product inside the m=4 path) == jnp.linalg.inv on SPD lane data."""
    rng = np.random.default_rng(m)
    lanes = 16
    A = rng.normal(size=(lanes, m, m))
    A = A @ np.swapaxes(A, -1, -2) + 3 * np.eye(m)
    S = [[jnp.asarray(A[:, i, j], jnp.float32) for j in range(m)]
         for i in range(m)]
    out = _emit_small_inv(S, m)
    got = np.stack([np.stack([np.asarray(out[i][j]) for j in range(m)],
                             axis=-1) for i in range(m)], axis=-2)
    want = np.asarray(jnp.linalg.inv(jnp.asarray(A, jnp.float32)))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


def test_make_kernel_rejects_general_H():
    """Non-selector measurement matrices fail fast at build time with a
    pointer to the batched_lanes stage (no dead general-H codepath)."""
    n, m = 4, 2
    rng = np.random.default_rng(0)
    model = FilterModel(
        name="dense-H", n=n, m=m, is_linear=True,
        F=np.eye(n), H=rng.normal(size=(m, n)), Q=np.eye(n) * 1e-2,
        R=np.eye(m) * 1e-1, x0=np.zeros(n), P0=np.eye(n))
    with pytest.raises(NotImplementedError, match="batched_lanes"):
        make_kernel(model)


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 32)])
@pytest.mark.parametrize("S,d,bq,bk", [(128, 32, 32, 32), (256, 64, 64, 128)])
def test_flash_attention_sweep(dtype, causal, window, S, d, bq, bk):
    rng = np.random.default_rng(0)
    B, H = 2, 2
    mk = lambda: jnp.asarray(rng.normal(size=(B, S, H, d)), dtype)  # noqa
    q, k, v = mk(), mk(), mk()
    scale = 1.0 / np.sqrt(d)
    o = flash_attention(q, k, v, scale, causal, window, bq, bk, True)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    want = attention_ref(qb, kb, vb, scale=scale, causal=causal,
                         window=window)
    want = want.reshape(B, H, S, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grads_match_dense():
    rng = np.random.default_rng(3)
    B, S, H, d = 1, 128, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    scale = 1.0 / np.sqrt(d)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, scale, True, None, 32, 32,
                                True) ** 2).sum()

    def loss_ref(q, k, v):
        qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
        kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
        vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, d)
        return (attention_ref(qb, kb, vb, scale=scale, causal=True) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


# -------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_naive(chunk, dtype):
    rng = np.random.default_rng(chunk)
    B, S, H, P, N = 2, 128, 2, 16, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    A = jnp.asarray(-np.exp(rng.normal(size=H)), jnp.float32)
    y = ssd_scan(x, dt, Bm, Cm, A, chunk=chunk)
    want, _ = ssd_naive(x, dt, Bm, Cm, A)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_equals_naive_hypothesis(B, H, seed):
    rng = np.random.default_rng(seed)
    S, P, N = 64, 8, 4
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    A = jnp.asarray(-np.exp(rng.normal(size=H)), jnp.float32)
    y1, s1 = ssd_chunked(x, dt, Bm, Cm, A, chunk=16)
    y2, s2 = ssd_naive(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-3)


# ----------------------------------------------------------- flash decode
@pytest.mark.parametrize("K", [2, 4])
@pytest.mark.parametrize("T,bk", [(128, 32), (256, 64)])
def test_flash_decode_matches_ref(K, T, bk):
    rng = np.random.default_rng(T + K)
    B, H, d = 2, 4, 32
    q = jnp.asarray(rng.normal(size=(B, 1, H, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, T, K, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, T, K, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, 1, K, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, 1, K, d)), jnp.float32)
    scale = 1.0 / np.sqrt(d)
    o = flash_decode(q, kc, vc, kn, vn, scale=scale, block_k=bk)
    want = flash_decode_ref(q, kc, vc, kn, vn, scale=scale)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_lse_merge_equals_monolithic():
    """Sharded partial softmax + LSE merge == single-pass softmax: the
    distributed flash-decode combiner is exact."""
    from repro.kernels.flash_decode.kernel import flash_decode_partial

    rng = np.random.default_rng(9)
    B, H, d, T = 1, 2, 16, 128
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, d)), jnp.float32)
    scale = 1.0 / np.sqrt(d)
    whole = flash_decode_partial(q, k, v, scale=scale, block_k=32)
    merged = lse_merge([
        flash_decode_partial(q, k[:, :64], v[:, :64], scale=scale,
                             block_k=32),
        flash_decode_partial(q, k[:, 64:], v[:, 64:], scale=scale,
                             block_k=32),
    ])
    want = whole[0] / whole[2]
    np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
