"""Fault-tolerance primitives (runtime/ft.py).

These are the coordination pieces the streaming front end and the
training launcher both lean on, driven with injected clocks and
induced failures so every path is deterministic:

  * HeartbeatMonitor — silence past the timeout declares a host dead,
    a beat resurrects it, remove() decommissions it for good;
  * StragglerDetector — EWMA-smoothed step times vs the fleet median,
    with removal of decommissioned hosts from the statistics;
  * TrainSupervisor — crash-restart around a step function with a
    bounded restart budget that re-raises once exhausted.
"""
import pytest

from repro.runtime.ft import (HeartbeatMonitor, StragglerDetector,
                              TrainSupervisor)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# --------------------------------------------------------------- heartbeat
class TestHeartbeatMonitor:
    def test_all_healthy_at_start(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a", "b"], timeout_s=1.0, clock=clk)
        assert mon.dead_hosts() == []
        assert mon.healthy()

    def test_silence_past_timeout_is_death(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a", "b"], timeout_s=1.0, clock=clk)
        clk.advance(0.9)
        mon.beat("a")
        clk.advance(0.5)  # a silent 0.5s, b silent 1.4s
        assert mon.dead_hosts() == ["b"]
        assert not mon.healthy()

    def test_beat_recovers_a_dead_host(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a"], timeout_s=1.0, clock=clk)
        clk.advance(2.0)
        assert mon.dead_hosts() == ["a"]
        mon.beat("a")  # the host came back before anyone failed it over
        assert mon.dead_hosts() == []

    def test_exact_timeout_is_not_dead(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a"], timeout_s=1.0, clock=clk)
        clk.advance(1.0)  # contract is strictly-greater-than
        assert mon.dead_hosts() == []

    def test_remove_decommissions_forever(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a", "b"], timeout_s=1.0, clock=clk)
        clk.advance(5.0)
        assert set(mon.dead_hosts()) == {"a", "b"}
        mon.remove("a")
        assert mon.dead_hosts() == ["b"]
        clk.advance(100.0)
        assert mon.dead_hosts() == ["b"]  # a never comes back
        mon.remove("missing")  # idempotent on unknown hosts

    def test_beats_keep_fleet_alive_indefinitely(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a", "b"], timeout_s=1.0, clock=clk)
        for _ in range(10):
            clk.advance(0.9)
            mon.beat("a")
            mon.beat("b")
        assert mon.healthy()


# --------------------------------------------------------------- straggler
class TestStragglerDetector:
    def test_needs_two_samples(self):
        det = StragglerDetector(["a", "b"])
        det.record("a", 1.0)
        assert det.stragglers() == []

    def test_flags_slow_host(self):
        det = StragglerDetector(["a", "b", "c"], k=2.0)
        for _ in range(5):
            det.record("a", 1.0)
            det.record("b", 1.0)
            det.record("c", 5.0)  # 5x the median
        assert det.stragglers() == ["c"]

    def test_ewma_smoothing_ignores_one_blip(self):
        det = StragglerDetector(["a", "b"], k=2.0, alpha=0.3)
        for _ in range(10):
            det.record("a", 1.0)
            det.record("b", 1.0)
        det.record("b", 3.0)  # one slow step: EWMA ~1.6 < 2x median
        assert det.stragglers() == []

    def test_ewma_converges_on_sustained_slowness(self):
        det = StragglerDetector(["a", "b", "c"], k=2.0, alpha=0.3)
        for _ in range(3):
            det.record("a", 1.0)
            det.record("b", 1.0)
            det.record("c", 1.0)
        for _ in range(20):  # c degrades for good
            det.record("a", 1.0)
            det.record("b", 1.0)
            det.record("c", 10.0)
        assert det.stragglers() == ["c"]

    def test_remove_drops_host_from_statistics(self):
        det = StragglerDetector(["a", "b", "c"], k=2.0)
        for _ in range(5):
            det.record("a", 1.0)
            det.record("b", 1.0)
            det.record("c", 9.0)
        assert det.stragglers() == ["c"]
        det.remove("c")  # failed over: its EWMA must not skew the rest
        assert det.stragglers() == []
        det.record("unknown", 1.0)  # late sample from a removed host
        det.remove("unknown")


# -------------------------------------------------------------- supervisor
class TestTrainSupervisor:
    def test_clean_run_no_restarts(self):
        ran = []
        sup = TrainSupervisor(ran.append, lambda: 0, total_steps=5)
        rep = sup.run()
        assert ran == [0, 1, 2, 3, 4]
        assert rep.steps_run == 5
        assert rep.restarts == 0

    def test_crash_restores_and_resumes(self):
        ran = []
        crashed = []

        def step(i):
            if i == 3 and not crashed:
                crashed.append(i)
                raise RuntimeError("induced")
            ran.append(i)

        sup = TrainSupervisor(step, lambda: 2, total_steps=5,
                              max_restarts=3)
        rep = sup.run()
        # restored to 2, re-ran 2 and 3, finished
        assert ran == [0, 1, 2, 2, 3, 4]
        assert rep.restarts == 1
        assert rep.restored_steps == [2]

    def test_restart_budget_exhaustion_reraises(self):
        def step(i):
            if i == 1:
                raise RuntimeError("persistent fault")

        sup = TrainSupervisor(step, lambda: 0, total_steps=3,
                              max_restarts=2)
        with pytest.raises(RuntimeError, match="persistent fault"):
            sup.run()

    def test_budget_counts_restarts_not_steps(self):
        crashes = []

        def step(i):
            # crash once at each of three different steps
            if i in (1, 2, 3) and i not in crashes:
                crashes.append(i)
                raise RuntimeError("induced")

        sup = TrainSupervisor(step, lambda: max(crashes) - 1,
                              total_steps=5, max_restarts=3)
        rep = sup.run()
        assert rep.restarts == 3
        # a fourth induced crash would have exceeded the budget
        assert rep.steps_run >= 5
