"""Checkpointing failure contract (checkpoint/ckpt.py).

The serving failover path trusts every clause of the module docstring,
so each one is induced here:

  * restore validates names/dtypes/shapes against the manifest and
    raises ``CheckpointMismatchError`` with a readable message instead
    of unflattening garbage into the wrong tree;
  * a crash mid-save leaves a ``.tmp_step_*`` dir behind and the NEXT
    save still commits atomically (and sweeps the garbage);
  * ``CheckpointManager.save(blocking=True)`` raises its own failure
    immediately; an async failure surfaces on the next call;
  * ``restore(step=None)`` survives a keep-N GC deleting the newest
    step out from under it (falls back to the next-newest survivor);
  * a successful commit is never failed retroactively by a GC hiccup.
"""
import json
import os
import shutil
import zipfile
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import ckpt as C


def _state(seed=0, n=4):
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(size=(n, 3)).astype(np.float32),
            "hits": np.arange(n, dtype=np.int32)}


def _roundtrip(tmp_path, state):
    C.save(str(tmp_path), 0, state)
    return C.restore(str(tmp_path), jax_like(state))


def jax_like(state):
    return {k: np.empty_like(v) for k, v in state.items()}


# -------------------------------------------------------------- validation
class TestRestoreValidation:
    def test_roundtrip_is_bitwise(self, tmp_path):
        state = _state()
        got, extra = _roundtrip(tmp_path, state)
        for k in state:
            np.testing.assert_array_equal(got[k], state[k])
        assert extra == {}

    def test_wrong_names_raise_with_both_sides(self, tmp_path):
        C.save(str(tmp_path), 0, _state())
        bad_like = {"x": np.empty((4, 3), np.float32),
                    "age": np.empty((4,), np.int32)}
        with pytest.raises(C.CheckpointMismatchError) as ei:
            C.restore(str(tmp_path), bad_like)
        msg = str(ei.value)
        assert "age" in msg and "hits" in msg  # names both directions

    def test_wrong_dtype_raises_named_leaf(self, tmp_path):
        C.save(str(tmp_path), 0, _state())
        like = _state()
        like["hits"] = like["hits"].astype(np.int64)
        with pytest.raises(C.CheckpointMismatchError, match="hits"):
            C.restore(str(tmp_path), like)

    def test_wrong_shape_raises_named_leaf(self, tmp_path):
        C.save(str(tmp_path), 0, _state(n=4))
        with pytest.raises(C.CheckpointMismatchError, match="hits"):
            C.restore(str(tmp_path), _state(n=8))

    def test_wrong_leaf_count_raises(self, tmp_path):
        C.save(str(tmp_path), 0, _state())
        with pytest.raises(C.CheckpointMismatchError):
            C.restore(str(tmp_path), {"x": np.empty((4, 3), np.float32)})

    def test_old_manifest_without_shapes_still_validates(self, tmp_path):
        d = C.save(str(tmp_path), 0, _state())
        man = json.loads((d / "manifest.json").read_text())
        del man["shapes"]  # manifests from before the shape record
        (d / "manifest.json").write_text(json.dumps(man))
        got, _ = C.restore(str(tmp_path), jax_like(_state()))
        np.testing.assert_array_equal(got["x"], _state()["x"])
        with pytest.raises(C.CheckpointMismatchError):
            C.restore(str(tmp_path), _state(n=8))  # shapes via arrays


# ------------------------------------------------------------- crash paths
class TestCrashMidSave:
    def test_stale_tmp_dir_does_not_block_next_save(self, tmp_path):
        root = Path(tmp_path)
        C.save(str(root), 0, _state(0))
        # a crashed save from another pid left its tmp dir behind
        stale = root / ".tmp_step_00000001_99999"
        stale.mkdir()
        (stale / "arrays.npz").write_bytes(b"half-written garbage")
        C.save(str(root), 1, _state(1))  # must commit atomically
        assert not stale.exists(), "stale tmp dir swept"
        got, _ = C.restore(str(root), jax_like(_state()))
        np.testing.assert_array_equal(got["x"], _state(1)["x"])
        assert C.available_steps(str(root)) == [0, 1]

    def test_tmp_dirs_never_count_as_steps(self, tmp_path):
        root = Path(tmp_path)
        C.save(str(root), 3, _state())
        (root / ".tmp_step_00000007_123").mkdir()
        assert C.available_steps(str(root)) == [3]

    def test_manager_init_sweeps_predecessor_garbage(self, tmp_path):
        root = Path(tmp_path)
        root.mkdir(exist_ok=True)
        (root / ".tmp_step_00000000_42").mkdir()
        C.CheckpointManager(str(root))
        assert list(root.glob(".tmp_step_*")) == []


# ---------------------------------------------------------- error ordering
class TestManagerErrorOrdering:
    def test_blocking_save_raises_immediately(self, tmp_path):
        mgr = C.CheckpointManager(str(tmp_path / "as_file"))
        (tmp_path / "as_file").write_text("not a directory")
        with pytest.raises(OSError):
            mgr.save(0, _state(), blocking=True)

    def test_async_error_surfaces_on_next_call_once(self, tmp_path):
        target = tmp_path / "as_file"
        mgr = C.CheckpointManager(str(target))
        target.write_text("not a directory")
        mgr.save(0, _state())  # async: returns despite doomed IO
        with pytest.raises(OSError):
            mgr.wait()
        mgr.wait()  # the error is raised once, not forever

    def test_async_error_surfaces_on_next_save(self, tmp_path):
        target = tmp_path / "as_file"
        mgr = C.CheckpointManager(str(target))
        target.write_text("not a directory")
        mgr.save(0, _state())
        with pytest.raises(OSError):
            mgr.save(1, _state())  # carries the PREVIOUS failure
        target.unlink()
        mgr.save(1, _state(), blocking=True)  # now healthy
        assert C.available_steps(str(target)) == [1]

    def test_gc_failure_never_fails_a_committed_save(self, tmp_path,
                                                     monkeypatch):
        mgr = C.CheckpointManager(str(tmp_path), keep_n=1)
        mgr.save(0, _state(0), blocking=True)

        def broken_gc():
            raise OSError("induced GC failure")

        monkeypatch.setattr(mgr, "_gc", broken_gc)
        with pytest.warns(RuntimeWarning, match="GC"):
            mgr.save(1, _state(1), blocking=True)  # commit still lands
        got, _ = mgr.restore_latest(jax_like(_state()))
        np.testing.assert_array_equal(got["x"], _state(1)["x"])


# ----------------------------------------------------------------- gc race
class TestRestoreGcRace:
    def test_newest_vanishing_falls_back(self, tmp_path, monkeypatch):
        for s in range(3):
            C.save(str(tmp_path), s, _state(s))
        real = C._load_step
        def racy(d, like):
            if d.name == "step_00000002":
                shutil.rmtree(d)  # GC wins the race on the newest
                raise FileNotFoundError(d)
            return real(d, like)
        monkeypatch.setattr(C, "_load_step", racy)
        got, _ = C.restore(str(tmp_path), jax_like(_state()))
        np.testing.assert_array_equal(got["x"], _state(1)["x"])

    def test_half_deleted_step_falls_back(self, tmp_path):
        for s in range(2):
            C.save(str(tmp_path), s, _state(s))
        # a GC got through the npz but not the manifest: listed, broken
        (Path(tmp_path) / "step_00000001" / "arrays.npz").unlink()
        got, _ = C.restore(str(tmp_path), jax_like(_state()))
        np.testing.assert_array_equal(got["x"], _state(0)["x"])

    def test_corrupt_npz_falls_back(self, tmp_path):
        for s in range(2):
            C.save(str(tmp_path), s, _state(s))
        (Path(tmp_path) / "step_00000001" / "arrays.npz").write_bytes(
            b"ZZ not a zip")
        got, _ = C.restore(str(tmp_path), jax_like(_state()))
        np.testing.assert_array_equal(got["x"], _state(0)["x"])

    def test_explicit_step_never_falls_back(self, tmp_path):
        for s in range(2):
            C.save(str(tmp_path), s, _state(s))
        (Path(tmp_path) / "step_00000001" / "arrays.npz").write_bytes(
            b"ZZ not a zip")
        with pytest.raises((zipfile.BadZipFile, OSError, ValueError)):
            C.restore(str(tmp_path), jax_like(_state()), step=1)

    def test_everything_gone_raises_not_loops(self, tmp_path):
        for s in range(2):
            C.save(str(tmp_path), s, _state(s))
        for s in range(2):
            (Path(tmp_path) / f"step_{s:08d}" / "arrays.npz").unlink()
        with pytest.raises((FileNotFoundError, OSError)):
            C.restore(str(tmp_path), jax_like(_state()))

    def test_no_checkpoints_at_all(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no checkpoints"):
            C.restore(str(tmp_path / "empty"), jax_like(_state()))


# ------------------------------------------------------------------ keep-n
def test_keep_n_gc(tmp_path):
    mgr = C.CheckpointManager(str(tmp_path), keep_n=2)
    for s in range(5):
        mgr.save(s, _state(s), blocking=True)
    assert C.available_steps(str(tmp_path)) == [3, 4]
    got, extra = mgr.restore_latest(jax_like(_state()))
    np.testing.assert_array_equal(got["x"], _state(4)["x"])


def test_extra_payload_roundtrips(tmp_path):
    C.save(str(tmp_path), 7, _state(),
           extra={"tenant": "t0", "frame": 7, "ns_base": 1 << 20})
    _, extra = C.restore(str(tmp_path), jax_like(_state()))
    assert extra == {"tenant": "t0", "frame": 7, "ns_base": 1 << 20}
