"""Filter-bank + MOT tracker system tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bank as bank_lib
from repro.core.filters import get_filter
from repro.core.tracker import TrackerConfig, greedy_assign, make_jitted_tracker
from repro.data.trajectories import SceneConfig, mot_scene


def test_greedy_assign_prefers_global_min():
    cost = jnp.asarray([[1.0, 5.0], [0.5, 9.0]])
    valid = jnp.ones((2, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(100.0), 2)
    # global min (slot1, meas0) commits first, slot0 takes meas1
    assert assoc.tolist() == [1, 0]


def test_greedy_assign_respects_gate():
    cost = jnp.asarray([[50.0, 60.0]])
    valid = jnp.ones((1, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(10.0), 1)
    assert assoc.tolist() == [-1]


@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_greedy_assign_is_matching(C, M, seed):
    """No measurement used twice; no slot assigned twice (it's a matching)."""
    rng = np.random.default_rng(seed)
    cost = jnp.asarray(rng.uniform(0, 10, (C, M)).astype(np.float32))
    valid = jnp.asarray(rng.random((C, M)) > 0.3)
    assoc = np.asarray(greedy_assign(cost, valid, jnp.asarray(8.0),
                                     min(C, M)))
    used = assoc[assoc >= 0]
    assert len(used) == len(set(used.tolist()))


def test_spawn_fills_free_slots_deterministically():
    model = get_filter("lkf")
    bank = bank_lib.init_bank(model, capacity=4)
    z = jnp.asarray(np.arange(12).reshape(4, 3), jnp.float32)
    unassigned = jnp.asarray([True, False, True, False])
    bank2 = bank_lib.spawn_tracks(model, bank, z, unassigned)
    assert bank2.active.tolist() == [True, True, False, False]
    np.testing.assert_allclose(np.asarray(bank2.x[0, :3]), [0, 1, 2])
    np.testing.assert_allclose(np.asarray(bank2.x[1, :3]), [6, 7, 8])
    assert bank2.track_id.tolist()[:2] == [0, 1]
    assert int(bank2.next_id) == 2


def test_prune_retires_coasted_tracks():
    model = get_filter("lkf")
    bank = bank_lib.init_bank(model, capacity=2)
    bank = bank._replace(active=jnp.asarray([True, True]),
                         misses=jnp.asarray([9, 0], jnp.int32))
    out = bank_lib.prune_bank(bank, max_misses=5)
    assert out.active.tolist() == [False, True]
    assert out.track_id.tolist()[0] == -1


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_mot_end_to_end(kind):
    """Tracker locks onto the true number of targets in a noisy scene."""
    model = get_filter(kind)
    cfg = TrackerConfig(capacity=32, max_meas=16)
    scene = SceneConfig(T=80, max_targets=4, max_meas=16, clutter_rate=0.3,
                        death_rate=0.0)
    z, valid, truth = mot_scene(model, scene, seed=7)
    init, step = make_jitted_tracker(model, cfg)
    bank = init()
    for t in range(scene.T):
        res = step(bank, jnp.asarray(z[t], jnp.float32), jnp.asarray(valid[t]))
        bank = res.bank
    n_true = len(truth[-1])
    n_confirmed = int(res.confirmed.sum())
    assert abs(n_confirmed - n_true) <= 1
    # slot-conservation invariant: ids never reused while active
    ids = np.asarray(bank.track_id)[np.asarray(bank.active)]
    assert len(ids) == len(set(ids.tolist()))


def test_bank_static_shapes_single_jit():
    """The whole frame step is one jittable function (KATANA: one
    inference call per frame), with zero retraces across frames."""
    import jax

    model = get_filter("lkf")
    cfg = TrackerConfig(capacity=16, max_meas=8)
    init, step = make_jitted_tracker(model, cfg)
    bank = init()
    z = jnp.zeros((8, 3), jnp.float32)
    v = jnp.zeros((8,), bool)
    step(bank, z, v)  # compile
    before = step._cache_size()
    for _ in range(3):
        res = step(bank, z, v)
        bank = res.bank
    assert step._cache_size() == before
