"""Filter-bank + MOT tracker system tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bank as bank_lib
from repro.core.filters import get_filter
from repro.core.rewrites import small_inv, stage_constants
from repro.core.tracker import (TrackerConfig, frame_step, greedy_assign,
                                make_jitted_tracker)
from repro.data.trajectories import SceneConfig, mot_scene


def test_greedy_assign_prefers_global_min():
    cost = jnp.asarray([[1.0, 5.0], [0.5, 9.0]])
    valid = jnp.ones((2, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(100.0), 2)
    # global min (slot1, meas0) commits first, slot0 takes meas1
    assert assoc.tolist() == [1, 0]


def test_greedy_assign_respects_gate():
    cost = jnp.asarray([[50.0, 60.0]])
    valid = jnp.ones((1, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(10.0), 1)
    assert assoc.tolist() == [-1]


def test_greedy_assign_all_gated_out():
    """Valid pairs whose costs all exceed the gate associate nothing."""
    cost = jnp.asarray([[20.0, 30.0], [25.0, 40.0]])
    valid = jnp.ones((2, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(10.0), 2)
    assert assoc.tolist() == [-1, -1]


def test_greedy_assign_zero_valid_measurements():
    """No valid measurement (empty frame) -> every slot unassigned,
    regardless of how cheap the costs look."""
    cost = jnp.zeros((3, 2))
    valid = jnp.zeros((3, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(100.0), 2)
    assert assoc.tolist() == [-1, -1, -1]


def test_greedy_assign_more_measurements_than_slots():
    """C < M: the single slot takes the global-min measurement; the
    rest stay unassigned (they spawn)."""
    cost = jnp.asarray([[5.0, 1.0, 3.0]])
    valid = jnp.ones((1, 3), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(100.0), 1)
    assert assoc.tolist() == [1]


def test_greedy_assign_more_slots_than_measurements():
    """M < C: only the best slot wins the lone measurement."""
    cost = jnp.asarray([[3.0], [1.0], [2.0]])
    valid = jnp.ones((3, 1), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(100.0), 1)
    assert assoc.tolist() == [-1, 0, -1]


def test_greedy_assign_tie_break_is_deterministic():
    """Equal costs: argmin over the flattened (row-major) cost commits
    the lowest (slot, measurement) pair first — stable across runs."""
    cost = jnp.ones((2, 2))
    valid = jnp.ones((2, 2), bool)
    assoc = greedy_assign(cost, valid, jnp.asarray(100.0), 2)
    assert assoc.tolist() == [0, 1]


@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_greedy_assign_is_matching(C, M, seed):
    """No measurement used twice; no slot assigned twice (it's a matching)."""
    rng = np.random.default_rng(seed)
    cost = jnp.asarray(rng.uniform(0, 10, (C, M)).astype(np.float32))
    valid = jnp.asarray(rng.random((C, M)) > 0.3)
    assoc = np.asarray(greedy_assign(cost, valid, jnp.asarray(8.0),
                                     min(C, M)))
    used = assoc[assoc >= 0]
    assert len(used) == len(set(used.tolist()))


@given(st.integers(1, 7), st.integers(1, 7), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_prop_greedy_assign_injective_and_gated(C, M, seed):
    """Property sweep of the two safety contracts at once: the
    assignment is an injective partial map slots -> measurements, and
    every committed pair is BOTH marked valid and within the gate —
    greedy never pairs through an invalid entry or past the chi-square
    radius, whatever the cost landscape."""
    rng = np.random.default_rng(seed)
    gate = float(rng.uniform(1.0, 9.0))
    cost = rng.uniform(0, 10, (C, M)).astype(np.float32)
    valid = rng.random((C, M)) > 0.4
    assoc = np.asarray(greedy_assign(jnp.asarray(cost), jnp.asarray(valid),
                                     jnp.asarray(gate), min(C, M)))
    assert assoc.shape == (C,)
    used = assoc[assoc >= 0]
    assert len(used) == len(set(used.tolist()))  # injective
    for c in range(C):
        if assoc[c] >= 0:
            assert valid[c, assoc[c]]
            assert cost[c, assoc[c]] <= gate


@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 4),
       st.integers(0, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_prop_greedy_assign_invariant_to_invalid_padding(C, M, pad_c, pad_m,
                                                         seed):
    """Padding the cost matrix with invalid rows (dead slots) and
    columns (padding measurements) changes NOTHING: the original slots
    get the identical assignment and the padding slots stay -1. This is
    the static-shape serving contract — a fleet-sized (capacity,
    max_meas) frame with most entries masked must associate exactly
    like the tight matrix."""
    rng = np.random.default_rng(seed)
    gate = 8.0
    cost = rng.uniform(0, 10, (C, M)).astype(np.float32)
    valid = rng.random((C, M)) > 0.3
    base = np.asarray(greedy_assign(jnp.asarray(cost), jnp.asarray(valid),
                                    jnp.asarray(gate), min(C, M)))
    # pad with garbage costs but valid=False — the mask must win
    cost_p = np.zeros((C + pad_c, M + pad_m), np.float32)
    cost_p[:C, :M] = cost
    cost_p[C:, :] = rng.uniform(0, 1, (pad_c, M + pad_m))  # temptingly cheap
    cost_p[:, M:] = rng.uniform(0, 1, (C + pad_c, pad_m))
    valid_p = np.zeros((C + pad_c, M + pad_m), bool)
    valid_p[:C, :M] = valid
    got = np.asarray(greedy_assign(jnp.asarray(cost_p), jnp.asarray(valid_p),
                                   jnp.asarray(gate),
                                   min(C + pad_c, M + pad_m)))
    np.testing.assert_array_equal(got[:C], base)
    assert (got[C:] == -1).all()


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_update_bank_recompute_fallback_matches_passthrough(kind):
    """``update_bank``'s standalone path (PHt=None / Sinv=None) must
    rebuild exactly the innovation quantities ``predict_bank`` hands the
    tracker — same S construction, same cofactor inverse — so a caller
    without the precomputed tensors gets bit-identical updates."""
    model = get_filter(kind)
    rng = np.random.default_rng(42)
    bank = bank_lib.init_bank(model, capacity=12)
    bank = bank._replace(
        active=jnp.asarray(rng.random(12) < 0.7),
        x=jnp.asarray(rng.normal(size=(12, model.n)), jnp.float32))
    bank_p, _, _, Sinv, PHt = bank_lib.predict_bank(model, bank)
    z = jnp.asarray(rng.normal(size=(6, model.m)), jnp.float32)
    assoc = jnp.asarray(rng.integers(-1, 6, size=12), jnp.int32)
    ref = bank_lib.update_bank(model, bank_p, z, assoc, PHt, Sinv)
    # each None independently, and both together, recompute to the same
    got_both = bank_lib.update_bank(model, bank_p, z, assoc)
    got_pht = bank_lib.update_bank(model, bank_p, z, assoc, None, Sinv)
    got_sinv = bank_lib.update_bank(model, bank_p, z, assoc, PHt, None)
    for got in (got_both, got_pht, got_sinv):
        np.testing.assert_array_equal(np.asarray(got.x), np.asarray(ref.x))
        np.testing.assert_array_equal(np.asarray(got.P), np.asarray(ref.P))
        np.testing.assert_array_equal(np.asarray(got.hits),
                                      np.asarray(ref.hits))


def test_spawn_fills_free_slots_deterministically():
    model = get_filter("lkf")
    bank = bank_lib.init_bank(model, capacity=4)
    z = jnp.asarray(np.arange(12).reshape(4, 3), jnp.float32)
    unassigned = jnp.asarray([True, False, True, False])
    bank2 = bank_lib.spawn_tracks(model, bank, z, unassigned)
    assert bank2.active.tolist() == [True, True, False, False]
    np.testing.assert_allclose(np.asarray(bank2.x[0, :3]), [0, 1, 2])
    np.testing.assert_allclose(np.asarray(bank2.x[1, :3]), [6, 7, 8])
    assert bank2.track_id.tolist()[:2] == [0, 1]
    assert int(bank2.next_id) == 2


def test_prune_retires_coasted_tracks():
    model = get_filter("lkf")
    bank = bank_lib.init_bank(model, capacity=2)
    bank = bank._replace(active=jnp.asarray([True, True]),
                         misses=jnp.asarray([9, 0], jnp.int32))
    out = bank_lib.prune_bank(bank, max_misses=5)
    assert out.active.tolist() == [False, True]
    assert out.track_id.tolist()[0] == -1


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_mot_end_to_end(kind):
    """Tracker locks onto the true number of targets in a noisy scene."""
    model = get_filter(kind)
    cfg = TrackerConfig(capacity=32, max_meas=16)
    scene = SceneConfig(T=80, max_targets=4, max_meas=16, clutter_rate=0.3,
                        death_rate=0.0)
    z, valid, truth = mot_scene(model, scene, seed=7)
    init, step = make_jitted_tracker(model, cfg)
    bank = init()
    for t in range(scene.T):
        res = step(bank, jnp.asarray(z[t], jnp.float32), jnp.asarray(valid[t]))
        bank = res.bank
    n_true = len(truth[-1])
    n_confirmed = int(res.confirmed.sum())
    assert abs(n_confirmed - n_true) <= 1
    # slot-conservation invariant: ids never reused while active
    ids = np.asarray(bank.track_id)[np.asarray(bank.active)]
    assert len(ids) == len(set(ids.tolist()))


def _legacy_frame_step(model, cfg, bank, z, z_valid):
    """Pre-refactor frame step: every phase rebuilds S / S^{-1} / P·Hᵀ
    from scratch (predict, gating, update each did their own). Kept as
    the regression oracle for the single-S hot path."""
    import jax.numpy as jnp
    from repro.core.tracker import CHI2_99

    dtype = jnp.dtype(cfg.dtype)
    gate = cfg.gate or CHI2_99.get(model.m, 16.0)
    C = stage_constants(model, dtype)
    # predict (own S)
    x, P = bank.x, bank.P
    if model.is_linear:
        x_pred = jnp.einsum("ij,kj->ki", C.F, x)
        FP = jnp.einsum("ij,kjl->kil", C.F, P)
        P_pred = jnp.einsum("kil,jl->kij", FP, C.F) + C.Q
    else:
        x_pred = model.predict_mean(x)
        Fk = model.jacobian(x)
        FP = jnp.einsum("kij,kjl->kil", Fk, P)
        P_pred = jnp.einsum("kil,kjl->kij", FP, Fk) + C.Q
    z_pred = jnp.einsum("mi,ki->km", C.H, x_pred)
    S = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
    bank_p = bank._replace(x=x_pred, P=P_pred)
    # gating (second S^{-1})
    Sinv = small_inv(S, model.m)
    y = z.astype(dtype)[None, :, :] - z_pred[:, None, :]
    cost = jnp.einsum("cMm,cmn,cMn->cM", y, Sinv, y)
    valid = bank_p.active[:, None] & z_valid[None, :]
    rounds = min(cfg.capacity, cfg.max_meas)
    assoc = greedy_assign(cost, valid, jnp.asarray(gate, dtype), rounds)
    # update (third S + third inversion)
    zz = z.astype(dtype)
    has_z = assoc >= 0
    zk = zz[jnp.clip(assoc, 0, zz.shape[0] - 1)]
    yk = zk + jnp.einsum("mi,ki->km", C.H_neg, x_pred)
    PHt = jnp.einsum("kij,mj->kim", P_pred, C.H)
    S2 = jnp.einsum("mi,kij,nj->kmn", C.H, P_pred, C.H) + C.R
    K = jnp.einsum("kim,kmn->kin", PHt, small_inv(S2, model.m))
    x_new = x_pred + jnp.einsum("kin,kn->ki", K, yk)
    HnP = jnp.einsum("mi,kij->kmj", C.H_neg, P_pred)
    P_new = P_pred + jnp.einsum("kim,kmj->kij", K, HnP)
    P_new = 0.5 * (P_new + jnp.swapaxes(P_new, -1, -2))
    upd = has_z & bank_p.active
    x_out = jnp.where(upd[:, None], x_new, x_pred)
    P_out = jnp.where(upd[:, None, None], P_new, P_pred)
    hits = jnp.where(upd, bank_p.hits + 1, bank_p.hits)
    misses = jnp.where(upd, 0, jnp.where(bank_p.active, bank_p.misses + 1,
                                         bank_p.misses))
    age = jnp.where(bank_p.active, bank_p.age + 1, bank_p.age)
    bank_u = bank_p._replace(x=x_out, P=P_out, hits=hits, misses=misses,
                             age=age)
    # spawn + prune (unchanged by the refactor)
    taken = jnp.zeros((cfg.max_meas,), bool).at[
        jnp.clip(assoc, 0, cfg.max_meas - 1)
    ].max(assoc >= 0)
    unassigned = z_valid & ~taken
    bank_s = bank_lib.spawn_tracks(model, bank_u, zz, unassigned, dtype)
    bank_f = bank_lib.prune_bank(bank_s, cfg.max_misses)
    confirmed = bank_f.active & (bank_f.hits >= cfg.min_hits)
    return bank_f, assoc, unassigned, confirmed


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_frame_step_single_S_regression(kind):
    """The single-S refactor (compute S / S^{-1} / P·Hᵀ once in
    predict_bank, reuse in gating + update) changes NOTHING numerically:
    frame-by-frame outputs match the legacy recompute-everything step
    over a full scene. Pinned to the EINSUM route — this is the oracle
    path's regression test; the fused kernel's own equivalence lives in
    tests/test_frame_kernel.py."""
    model = get_filter(kind)
    cfg = TrackerConfig(capacity=16, max_meas=8, fused_frame=False)
    scene = SceneConfig(T=30, max_targets=3, max_meas=8, clutter_rate=0.5,
                        death_rate=0.0)
    z, valid, _ = mot_scene(model, scene, seed=13)
    bank_new = bank_lib.init_bank(model, cfg.capacity)
    bank_old = bank_lib.init_bank(model, cfg.capacity)
    for t in range(scene.T):
        zt = jnp.asarray(z[t], jnp.float32)
        vt = jnp.asarray(valid[t])
        res = frame_step(model, cfg, bank_new, zt, vt)
        old_bank, old_assoc, old_unassigned, old_confirmed = \
            _legacy_frame_step(model, cfg, bank_old, zt, vt)
        np.testing.assert_array_equal(np.asarray(res.assoc),
                                      np.asarray(old_assoc))
        np.testing.assert_array_equal(np.asarray(res.confirmed),
                                      np.asarray(old_confirmed))
        np.testing.assert_allclose(np.asarray(res.bank.x),
                                   np.asarray(old_bank.x), atol=1e-6)
        np.testing.assert_allclose(np.asarray(res.bank.P),
                                   np.asarray(old_bank.P), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(res.bank.track_id),
                                      np.asarray(old_bank.track_id))
        bank_new, bank_old = res.bank, old_bank


def test_frame_step_inverts_S_exactly_once(monkeypatch):
    """Trace-level guarantee of the single-pass hot path: one frame_step
    triggers exactly ONE innovation-covariance inversion (small_inv) —
    gating and update reuse it rather than recomputing."""
    calls = []
    real = bank_lib.small_inv

    def counting(M, dim):
        calls.append(dim)
        return real(M, dim)

    monkeypatch.setattr(bank_lib, "small_inv", counting)
    model = get_filter("lkf")
    # einsum route: the fused kernel emits its (single) inversion inside
    # the Pallas body, invisible to this trace-level counter
    cfg = TrackerConfig(capacity=8, max_meas=4, fused_frame=False)
    bank = bank_lib.init_bank(model, cfg.capacity)
    z = jnp.asarray(np.random.default_rng(0).normal(size=(4, model.m)),
                    jnp.float32)
    frame_step(model, cfg, bank, z, jnp.ones((4,), bool))  # eager trace
    assert calls == [model.m]


def test_bank_static_shapes_single_jit():
    """The whole frame step is one jittable function (KATANA: one
    inference call per frame), with zero retraces across frames."""
    import jax

    model = get_filter("lkf")
    cfg = TrackerConfig(capacity=16, max_meas=8)
    init, step = make_jitted_tracker(model, cfg)
    bank = init()
    z = jnp.zeros((8, 3), jnp.float32)
    v = jnp.zeros((8,), bool)
    step(bank, z, v)  # compile
    before = step._cache_size()
    for _ in range(3):
        res = step(bank, z, v)
        bank = res.bank
    assert step._cache_size() == before
