"""End-to-end behaviour: the paper's full story on one synthetic scene.

Baseline -> Opt1 -> Opt2 -> Batched produce the SAME track; the batched
bank serves a multi-object scene in real time; and the fused kernel is
a drop-in for the bank update. This is the Fig. 1 pipeline as a test.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ref
from repro.core.filters import get_filter
from repro.core.rewrites import STAGES, run_sequence
from repro.core.tracker import TrackerConfig
from repro.data.trajectories import SceneConfig, mot_scene, single_target
from repro.kernels.katana_bank.ops import katana_bank
from repro.serving.engine import TrackingEngine


def test_paper_pipeline_end_to_end():
    model = get_filter("ekf")
    # 1) all rewrite stages = one filter
    truth, zs = single_target(model, 80, seed=11)
    want, _ = ref.run(model, zs)
    for stage in STAGES:
        got = np.asarray(run_sequence(model, stage, zs[:, None, :],
                                      np.tile(model.x0, (1, 1)),
                                      np.tile(model.P0, (1, 1, 1))))[:, 0]
        np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)

    # 2) the fused kernel steps a 200-filter bank identically
    N = 200
    x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    z = jnp.asarray(np.tile(zs[0], (N, 1)), jnp.float32)
    xk, Pk = katana_bank(model, x, P, z)
    x1, P1 = ref.step(model, np.asarray(model.x0), np.asarray(model.P0),
                      zs[0])
    np.testing.assert_allclose(np.asarray(xk[0]), x1, atol=1e-4)

    # 3) the serving engine tracks a live scene (Fig. 5 analogue)
    engine = TrackingEngine(model, TrackerConfig(capacity=32, max_meas=16))
    scene = SceneConfig(T=60, max_targets=3, max_meas=16, death_rate=0.0)
    zmat, valid, truth_scene = mot_scene(model, scene, seed=4)
    for t in range(scene.T):
        k = int(valid[t].sum())
        tracks = engine.submit(zmat[t][valid[t]][:k])
    assert abs(len(tracks) - len(truth_scene[-1])) <= 1
    # real-time: well under the paper's 33 ms frame budget even on CPU
    assert engine.stats.fps > 30

    # 4) offline replay: the whole stream through ONE fused scan
    # dispatch reproduces the float64 oracle track
    replayed = engine.replay(zs[:, None, :])
    np.testing.assert_allclose(replayed[:, 0], want, atol=5e-4, rtol=5e-4)
