"""Fused IMM scan (`imm_scan` stage): the whole mix -> predict/update
-> mode-posterior cycle inside one Pallas dispatch must be numerically
indistinguishable from the per-frame driver and the float64 oracle,
reduce bitwise to the single-model fused scan at K=1, and implement the
tracker's coasting semantics on no-measurement frames."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref as oref
from repro.core.bank import init_imm_bank, replay_imm_bank
from repro.core.filters import as_imm, get_filter, make_imm
from repro.core.rewrites import run_sequence
from repro.data.trajectories import maneuvering_batch
from repro.kernels.katana_bank.ops import (imm_bank_sequence,
                                           katana_bank_sequence,
                                           katana_imm_sequence)


def _seq_inputs(model, N, dtype=jnp.float32):
    x0 = jnp.asarray(np.tile(model.x0, (N, 1)), dtype)
    P0 = jnp.asarray(np.tile(model.P0, (N, 1, 1)), dtype)
    return x0, P0


def test_imm_scan_matches_per_frame_driver_and_oracle():
    """One-dispatch fused IMM == the lax.scan per-frame driver (the
    independently built mix -> katana_bank_imm -> posterior pipeline)
    AND the textbook float64 recursion, states and final mode probs."""
    imm = make_imm()
    T, N = 40, 5
    rng = np.random.default_rng(3)
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    zsf = jnp.asarray(zs, jnp.float32)
    x0, P0 = _seq_inputs(imm, N)
    got, (xf, Pf, muf) = katana_imm_sequence(imm, zsf, x0, P0,
                                             return_final=True)
    drv = imm_bank_sequence(imm, zsf, x0, P0, lane_tile=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(drv),
                               atol=2e-5, rtol=2e-4)
    want, mus = oref.run_imm_batched(imm, zs, np.asarray(x0), np.asarray(P0))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(muf), mus[-1], atol=1e-5)
    assert xf.shape == (imm.K, N, imm.n)
    assert Pf.shape == (imm.K, N, imm.n, imm.n)


def test_imm_scan_on_maneuvering_scene_tracks_driver():
    """Same equivalence on the CV/CT/CA switching scene (mode
    probabilities actually move here, so the in-kernel posterior and
    mixing are exercised away from the uniform fixed point)."""
    imm = make_imm()
    T, N = 48, 4
    truth, zs = maneuvering_batch(T, N, seed=7)
    zsf = jnp.asarray(zs, jnp.float32)
    x0, P0 = _seq_inputs(imm, N)
    got = np.asarray(katana_imm_sequence(imm, zsf, x0, P0))
    drv = np.asarray(imm_bank_sequence(imm, zsf, x0, P0, lane_tile=128))
    np.testing.assert_allclose(got, drv, atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("kind", ["cv9", "ekf"])
def test_imm_scan_k1_reduces_to_fused_scan(kind):
    """K=1 emits exactly make_scan_kernel's op stream — bitwise equal to
    katana_bank_sequence, including the nonlinear CTRA member."""
    model = get_filter(kind)
    T, N = 30, 6
    rng = np.random.default_rng(11)
    zs = jnp.asarray(rng.normal(size=(T, N, model.m)) * 0.5, jnp.float32)
    x0, P0 = _seq_inputs(model, N)
    got = np.asarray(katana_imm_sequence(as_imm(model), zs, x0, P0,
                                         lane_tile=128))
    plain = np.asarray(katana_bank_sequence(model, zs, x0, P0,
                                            lane_tile=128))
    np.testing.assert_array_equal(got, plain)


def test_imm_scan_coasting_frames_match_oracle():
    """valid=False frames coast: time update only, mu <- the
    Markov-predicted cbar — the float64 oracle extended with the same
    semantics must agree, including tracks coasting while others
    update in the same frame."""
    imm = make_imm()
    T, N = 36, 4
    rng = np.random.default_rng(5)
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    valid = np.ones((T, N), bool)
    valid[6] = False          # whole frame dropped
    valid[20, ::2] = False    # half the tracks coast
    valid[28:31, 1] = False   # one track coasts three frames straight
    zsf = jnp.asarray(zs, jnp.float32)
    x0, P0 = _seq_inputs(imm, N)
    got = np.asarray(katana_imm_sequence(imm, zsf, x0, P0,
                                         valid=jnp.asarray(valid)))
    want, _ = oref.run_imm_batched(imm, zs, np.asarray(x0), np.asarray(P0),
                                   valid=valid)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    assert np.isfinite(got).all()


def test_imm_scan_coasting_tolerates_nan_measurements():
    """A replay log that encodes 'no detection' as NaN must not poison
    the carry: invalid frames' z is masked before the kernel, so the
    result equals the same stream with zeros in the invalid slots."""
    imm = make_imm()
    T, N = 20, 3
    rng = np.random.default_rng(2)
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    valid = np.ones((T, N), bool)
    valid[5] = False
    valid[12, 0] = False
    zs_nan = zs.copy()
    zs_nan[~valid] = np.nan
    x0, P0 = _seq_inputs(imm, N)
    got = np.asarray(katana_imm_sequence(imm, jnp.asarray(zs_nan, jnp.float32),
                                         x0, P0, valid=jnp.asarray(valid)))
    assert np.isfinite(got).all()
    ref_run = np.asarray(katana_imm_sequence(imm, jnp.asarray(zs, jnp.float32),
                                             x0, P0,
                                             valid=jnp.asarray(valid)))
    np.testing.assert_array_equal(got, ref_run)


def test_imm_scan_unreachable_mode_column():
    """A transition matrix with an all-zero column (a mode that can be
    left but never entered) folds that mode's whole mixing slab to the
    constant 0 — the kernel must still trace and stay finite, with the
    dead mode's posterior weight exactly 0 (same contract as
    rewrites.imm_mix)."""
    from repro.core.filters import IMMModel, make_ca9_lkf, make_cv9_lkf

    cv, ca = make_cv9_lkf(), make_ca9_lkf()
    trans = np.array([[1.0, 0.0], [1.0, 0.0]])  # mode 1 unreachable
    imm = IMMModel(name="dead-col", models=(cv, ca), trans=trans,
                   mu0=np.array([1.0, 0.0]))
    T, N = 12, 2
    rng = np.random.default_rng(4)
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    x0, P0 = _seq_inputs(imm, N)
    got, (_, _, muf) = katana_imm_sequence(imm, jnp.asarray(zs, jnp.float32),
                                           x0, P0, return_final=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_array_equal(np.asarray(muf)[:, 1], 0.0)
    drv = imm_bank_sequence(imm, jnp.asarray(zs, jnp.float32), x0, P0,
                            lane_tile=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(drv),
                               atol=2e-5, rtol=2e-4)


def test_imm_scan_chunked_streaming_is_exact():
    """time_chunk splits a stream into several dispatches with
    (x, P, mu) carried between them — bitwise identical to one
    dispatch, so VMEM-bounded chunking is free."""
    imm = make_imm()
    T, N = 30, 3
    rng = np.random.default_rng(9)
    zs = jnp.asarray(rng.normal(size=(T, N, imm.m)) * 0.5, jnp.float32)
    x0, P0 = _seq_inputs(imm, N)
    one = np.asarray(katana_imm_sequence(imm, zs, x0, P0, time_chunk=64))
    many = np.asarray(katana_imm_sequence(imm, zs, x0, P0, time_chunk=7))
    np.testing.assert_array_equal(one, many)


def test_imm_scan_stage_in_run_sequence():
    """The 'imm_scan' rewrites stage drives through the uniform
    run_sequence entry point and tracks the float64 oracle."""
    imm = make_imm()
    T, N = 30, 4
    rng = np.random.default_rng(13)
    zs = rng.normal(size=(T, N, imm.m)) * 0.5
    x0 = np.tile(imm.x0, (N, 1))
    P0 = np.tile(imm.P0, (N, 1, 1))
    got = np.asarray(run_sequence(imm, "imm_scan", zs, x0, P0))
    want, _ = oref.run_imm_batched(imm, zs, x0, P0)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_replay_imm_bank_resumes_mode_conditioned_state():
    """bank.replay_imm_bank seeds the fused scan from a live
    IMMBankState (mode-conditioned x/P + mu) — equivalent to running
    the whole stream through one katana_imm_sequence call."""
    imm = make_imm()
    C, T = 3, 24
    rng = np.random.default_rng(17)
    zs = jnp.asarray(rng.normal(size=(T, C, imm.m)) * 0.5, jnp.float32)
    x0, P0 = _seq_inputs(imm, C)
    # run half the stream, reseed a bank from the finals, run the rest
    first, (xh, Ph, muh) = katana_imm_sequence(imm, zs[:T // 2], x0, P0,
                                               return_final=True)
    bank = init_imm_bank(imm, C)._replace(x=xh, P=Ph, mu=muh)
    rest = replay_imm_bank(imm, bank, zs[T // 2:])
    whole = katana_imm_sequence(imm, zs, x0, P0)
    np.testing.assert_allclose(np.asarray(rest),
                               np.asarray(whole)[T // 2:],
                               atol=1e-6, rtol=1e-6)


def test_imm_engine_replay_uses_fused_scan():
    """TrackingEngine.replay for an IMM model routes through
    katana_imm_sequence and agrees with the per-frame driver."""
    from repro.core.tracker import TrackerConfig
    from repro.serving.engine import TrackingEngine

    imm = make_imm()
    eng = TrackingEngine(imm, TrackerConfig(capacity=8, max_meas=4))
    T, N = 20, 2
    rng = np.random.default_rng(21)
    zs = (rng.normal(size=(T, N, imm.m)) * 0.5).astype(np.float32)
    out = eng.replay(zs)
    assert out.shape == (T, N, imm.n)
    x0, P0 = _seq_inputs(imm, N)
    drv = imm_bank_sequence(imm, jnp.asarray(zs), x0, P0, lane_tile=128)
    np.testing.assert_allclose(out, np.asarray(drv), atol=2e-5, rtol=2e-4)
    assert eng.stats.replay_frames == T
