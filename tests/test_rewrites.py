"""Stage-equivalence: every KATANA rewrite is an exact algebraic
transform — all stages must track the float64 oracle, and hypothesis
sweeps random linear systems through the rewrite algebra."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import ref
from repro.core.filters import FilterModel, get_filter
from repro.core.rewrites import (
    STAGES,
    block_diag_batched,
    build_stage,
    extract_diag_blocks,
    run_sequence,
    small_inv,
)

TOL = 2e-4  # fp32 vs fp64 over 50 recursions


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
@pytest.mark.parametrize("stage", STAGES)
def test_stage_matches_oracle(kind, stage):
    model = get_filter(kind)
    rng = np.random.default_rng(0)
    T = 50
    N = 1 if stage in ("baseline", "opt1", "opt2") else 8
    zs = rng.normal(size=(T, N, model.m)) * 0.5
    x0 = np.tile(model.x0, (N, 1)) + rng.normal(size=(N, model.n)) * 0.1
    P0 = np.tile(model.P0, (N, 1, 1))
    want, _, _ = ref.run_batched(model, zs, x0, P0)
    got = np.asarray(run_sequence(model, stage, zs, x0, P0))
    np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_blockdiag_equals_lanes(kind):
    """Paper batching and TPU-native batching are numerically twins."""
    model = get_filter(kind)
    rng = np.random.default_rng(1)
    T, N = 30, 16
    zs = rng.normal(size=(T, N, model.m)) * 0.5
    x0 = np.tile(model.x0, (N, 1)) + rng.normal(size=(N, model.n)) * 0.1
    P0 = np.tile(model.P0, (N, 1, 1))
    bd = np.asarray(run_sequence(model, "batched_blockdiag", zs, x0, P0))
    ln = np.asarray(run_sequence(model, "batched_lanes", zs, x0, P0))
    np.testing.assert_allclose(bd, ln, atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dim", [1, 2, 3, 4])
def test_small_inv_matches_numpy(dim):
    rng = np.random.default_rng(dim)
    A = rng.normal(size=(32, dim, dim))
    A = A @ np.swapaxes(A, -1, -2) + 3 * np.eye(dim)  # well-conditioned SPD
    got = np.asarray(small_inv(jnp.asarray(A, jnp.float32), dim))
    want = np.linalg.inv(A)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


@given(st.integers(1, 12), st.integers(1, 5), st.integers(1, 5),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_blockdiag_roundtrip(N, a, b, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.normal(size=(N, a, b)).astype(np.float32)
    bd = np.asarray(block_diag_batched(jnp.asarray(blocks)))
    assert bd.shape == (N * a, N * b)
    # diagonal blocks round-trip; off-diagonal blocks are zero
    if a == b:
        back = np.asarray(extract_diag_blocks(jnp.asarray(bd), N, a))
        np.testing.assert_allclose(back, blocks)
    mask = np.kron(np.eye(N), np.ones((a, b)))
    np.testing.assert_allclose(bd * (1 - mask), 0)


@given(st.integers(2, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_random_linear_system_stage_equivalence(m, seed):
    """hypothesis: random stable linear systems — opt2 == oracle."""
    rng = np.random.default_rng(seed)
    n = m + rng.integers(0, 3)
    A = rng.normal(size=(n, n))
    F = 0.9 * A / max(1.0, np.max(np.abs(np.linalg.eigvals(A))))
    H = rng.normal(size=(m, n))
    Q = np.eye(n) * 10.0 ** rng.uniform(-4, -1)
    R = np.eye(m) * 10.0 ** rng.uniform(-3, 0)
    model = FilterModel(
        name="rand", n=n, m=m, is_linear=True, F=F, H=H, Q=Q, R=R,
        x0=np.zeros(n), P0=np.eye(n))
    zs = rng.normal(size=(20, 1, m))
    x0 = np.zeros((1, n))
    P0 = np.tile(model.P0, (1, 1, 1))
    want, _, _ = ref.run_batched(model, zs, x0, P0)
    got = np.asarray(run_sequence(model, "opt2", zs, x0, P0))
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-2)


# ---------------------------------------------------------------------------
# Property-based IMM algebra invariants (rewrites.imm_*). These are the
# contracts every fused path (imm_bank / imm_scan kernels, the sharded
# serving engine) inherits — run via tests/_hypothesis_compat, so they
# degrade to fixed-seed parametrized cases when hypothesis is absent.
# ---------------------------------------------------------------------------

def _imm_random(K, B, n, rng, dirichlet=True):
    """Random mode-conditioned states: x (K, B, n), PSD P (K, B, n, n),
    normalized mu (B, K), row-stochastic Pi (K, K)."""
    x = rng.normal(size=(K, B, n)).astype(np.float32)
    A = rng.normal(size=(K, B, n, n)) * 0.4
    P = (A @ A.transpose(0, 1, 3, 2) + np.eye(n)).astype(np.float32)
    mu = (rng.random((B, K)) + 1e-3).astype(np.float32)
    mu /= mu.sum(1, keepdims=True)
    Pi = (rng.random((K, K)) + 1e-3).astype(np.float32)
    Pi /= Pi.sum(1, keepdims=True)
    return x, P, mu, Pi


@given(st.integers(1, 5), st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_prop_imm_mode_posterior_normalized_nonnegative(K, B, seed):
    """mu' = posterior(cbar, loglik) is a distribution for ANY finite
    log-likelihoods (the shift-stable exp never over/underflows all
    modes at once): rows sum to 1, entries in [0, 1], no NaN."""
    from repro.core.rewrites import imm_mode_posterior

    rng = np.random.default_rng(seed)
    _, _, cbar, _ = _imm_random(K, B, 2, rng)
    # wild dynamic range, incl. the hugely-negative logliks a gated-out
    # mode produces
    loglik = (rng.uniform(-1e4, 1e2, size=(K, B))).astype(np.float32)
    mu = np.asarray(imm_mode_posterior(jnp.asarray(cbar),
                                       jnp.asarray(loglik)))
    assert np.isfinite(mu).all()
    assert (mu >= 0).all() and (mu <= 1 + 1e-6).all()
    np.testing.assert_allclose(mu.sum(1), 1.0, atol=1e-5)


@given(st.integers(1, 4), st.integers(1, 5), st.integers(2, 6),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_prop_imm_combine_covariance_symmetric_psd(K, B, n, seed):
    """The moment-matched mixture covariance is symmetric PSD whenever
    the per-mode covariances are (the spread term can only ADD
    dispersion), and the mean is inside the convex hull of the
    per-mode means."""
    from repro.core.rewrites import imm_combine

    rng = np.random.default_rng(seed)
    x, P, mu, _ = _imm_random(K, B, n, rng)
    x_c, P_c = imm_combine(jnp.asarray(x), jnp.asarray(P), jnp.asarray(mu))
    x_c, P_c = np.asarray(x_c), np.asarray(P_c)
    assert np.isfinite(P_c).all()
    for b in range(B):
        np.testing.assert_allclose(P_c[b], P_c[b].T, atol=1e-4)
        assert np.linalg.eigvalsh(P_c[b].astype(np.float64)).min() > -1e-3
        assert (x_c[b] <= x[:, b].max(0) + 1e-5).all()
        assert (x_c[b] >= x[:, b].min(0) - 1e-5).all()


@given(st.integers(2, 4), st.integers(1, 5), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_prop_imm_mix_permutation_equivariant(K, B, seed):
    """Relabeling the K models (permuting x/P slabs, mu columns, and
    both axes of the transition matrix) permutes imm_mix's outputs the
    same way — the mixing algebra carries no hidden model-order
    dependence. Exercised with n=4 states."""
    from repro.core.rewrites import imm_mix

    n = 4
    rng = np.random.default_rng(seed)
    x, P, mu, Pi = _imm_random(K, B, n, rng)
    perm = rng.permutation(K)
    xm, Pm, cbar = imm_mix(jnp.asarray(x), jnp.asarray(P), jnp.asarray(mu),
                           jnp.asarray(Pi))
    xm2, Pm2, cbar2 = imm_mix(jnp.asarray(x[perm]), jnp.asarray(P[perm]),
                              jnp.asarray(mu[:, perm]),
                              jnp.asarray(Pi[np.ix_(perm, perm)]))
    np.testing.assert_allclose(np.asarray(xm2), np.asarray(xm)[perm],
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(Pm2), np.asarray(Pm)[perm],
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cbar2), np.asarray(cbar)[:, perm],
                               atol=1e-6)


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_covariance_stays_psd(kind):
    model = get_filter(kind)
    rng = np.random.default_rng(2)
    N, T = 4, 80
    step, _ = build_stage(model, "batched_lanes", N=N, symmetrize=True)
    x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    for t in range(T):
        z = jnp.asarray(rng.normal(size=(N, model.m)), jnp.float32)
        x, P = step(x, P, z)
    Pn = np.asarray(P)
    for k in range(N):
        np.testing.assert_allclose(Pn[k], Pn[k].T, atol=1e-5)
        assert np.linalg.eigvalsh(Pn[k]).min() > -1e-5
