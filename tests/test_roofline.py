"""The dormant roofline package gets a test floor, plus the katana
wiring that now consumes it.

hlo.py's census parsers were written against dry-run artifacts this
repo never ships, so until now nothing executed them: every regex is
exercised here on hand-built HLO lines (explicit and iota
replica_groups, tuple results, dtype byte widths) AND on a real
compiled katana_bank program. analysis.py's three-term model is pinned
on dominance arithmetic and the per-backend Machine selection that
benchmarks/roofline.py uses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (HBM_BW, ICI_BW, MACHINES,
                                     PEAK_FLOPS_BF16, Machine,
                                     machine_for_backend, terms_from,
                                     terms_on)
from repro.roofline.hlo import (collective_census, cpu_upcast_bytes,
                                op_census, totals)

# ---------------------------------------------------------------------------
# hlo.py census on synthetic HLO text
# ---------------------------------------------------------------------------

HLO = """\
HloModule m
  %x = f32[8,128]{1,0} parameter(0)
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={0}
  %tup = (f32[4,4]{1,0}, s32[4]{0}) all-to-all(%a, %b), replica_groups={{0,1}}
  %cp = f32[2,2]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
  %d = f32[8,8]{1,0} dot(%x, %y), lhs_contracting_dims={1}
  %t = f32[128,8]{0,1} transpose(%x), dimensions={1,0}
  %add.1 = f32[8,128]{1,0} add(%x, %x)
"""


def test_collective_census_explicit_groups_all_reduce():
    c = collective_census(HLO)
    ar = c["all-reduce"]
    rb = 8 * 128 * 4
    assert ar["count"] == 1
    assert ar["result_bytes"] == rb
    assert ar["operand_bytes"] == rb
    # ring all-reduce: 2·B·(g-1)/g with g=4 from the explicit groups
    assert ar["wire_bytes"] == pytest.approx(2.0 * rb * 3 / 4)
    # f32 payload counts at half weight in the bf16-equivalent column
    assert ar["wire_bytes_bf16eq"] == pytest.approx(ar["wire_bytes"] * 0.5)


def test_collective_census_iota_groups_and_dtype_bytes():
    c = collective_census(HLO)
    ag = c["all-gather"]
    rb = 16 * 128 * 2  # bf16 = 2 bytes
    assert ag["result_bytes"] == rb
    # iota [2,4]<=[8]: group size 4
    assert ag["operand_bytes"] == pytest.approx(rb / 4)
    assert ag["wire_bytes"] == pytest.approx(rb * 3 / 4)
    # bf16 stays at full weight in the bf16-equivalent column
    assert ag["wire_bytes_bf16eq"] == pytest.approx(ag["wire_bytes"])


def test_collective_census_tuple_result():
    c = collective_census(HLO)
    a2a = c["all-to-all"]
    rb = 4 * 4 * 4 + 4 * 4  # f32[4,4] + s32[4]
    assert a2a["result_bytes"] == rb
    assert a2a["wire_bytes"] == pytest.approx(rb * 1 / 2)  # g=2


def test_collective_census_permute_and_totals():
    c = collective_census(HLO)
    cp = c["collective-permute"]
    assert cp["wire_bytes"] == cp["result_bytes"] == 2 * 2 * 4
    t = totals(c)
    assert t["count"] == 4
    assert t["wire_bytes"] == pytest.approx(
        sum(d["wire_bytes"] for d in c.values()))


def test_collective_census_start_done_counted_once():
    text = """\
  %s = f32[8]{0} all-reduce-start(%x), replica_groups={{0,1}}
  %d = f32[8]{0} all-reduce-done(%s)
"""
    c = collective_census(text)
    assert c["all-reduce"]["count"] == 1


def test_op_census_counts_kinds():
    c = op_census(HLO)
    assert c["dot"] == 1
    assert c["transpose"] == 1
    assert c["add"] == 1
    assert c["scatter"] == 0
    # collectives are not in the default op list
    assert "all-reduce" not in c


def test_cpu_upcast_bytes_thresholds():
    text = "  %c = f32[4096,4096]{1,0} convert(%w)\n" \
           "  %small = f32[4]{0} convert(%v)\n"
    big = 4096 * 4096 * 4
    assert cpu_upcast_bytes(text, min_bytes=1e6) == big
    assert cpu_upcast_bytes(text, min_bytes=big + 1) == 0.0


# ---------------------------------------------------------------------------
# analysis.py three-term model + Machine selection
# ---------------------------------------------------------------------------

def test_terms_from_dominance_and_bound():
    # memory-dominated: tiny flops, huge bytes
    t = terms_from(flops_dev=1e9, bytes_dev=1e12, coll_wire_bytes_dev=0.0)
    assert t.dominant == "memory"
    assert t.bound == pytest.approx(1e12 / HBM_BW)
    # compute-dominated
    t = terms_from(flops_dev=1e15, bytes_dev=1.0, coll_wire_bytes_dev=0.0)
    assert t.dominant == "compute"
    assert t.bound == pytest.approx(1e15 / PEAK_FLOPS_BF16)
    # collective-dominated
    t = terms_from(flops_dev=1.0, bytes_dev=1.0, coll_wire_bytes_dev=1e12)
    assert t.dominant == "collective"
    assert t.bound == pytest.approx(1e12 / ICI_BW)


def test_useful_and_roofline_fractions():
    t = terms_from(flops_dev=2e12, bytes_dev=1.0, coll_wire_bytes_dev=0.0,
                   model_flops_dev=1e12)
    assert t.useful_fraction == pytest.approx(0.5)
    # compute-bound: roofline fraction equals useful fraction
    assert t.roofline_fraction == pytest.approx(0.5)


def test_terms_on_uses_machine_peaks():
    m = Machine("toy", peak_flops=1e9, mem_bw=1e6, ici_bw=0.0)
    t = terms_on(m, flops_dev=1e9, bytes_dev=2e6, model_flops_dev=5e8)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(2.0)
    assert t.t_collective == 0.0  # ici_bw 0 disables the term
    assert t.dominant == "memory"
    # roofline_fraction must use the MACHINE's peak, not the TPU const
    assert t.roofline_fraction == pytest.approx(5e8 / (2.0 * 1e9))


def test_machine_for_backend_mapping():
    assert machine_for_backend("tpu") is MACHINES["tpu_v5e"]
    assert machine_for_backend("tpu_v5e") is MACHINES["tpu_v5e"]
    assert machine_for_backend("cpu") is MACHINES["cpu"]
    assert machine_for_backend("unknown-thing") is MACHINES["cpu"]


# ---------------------------------------------------------------------------
# census smoke on a REAL compiled katana program
# ---------------------------------------------------------------------------

def test_census_on_compiled_katana_bank():
    """The parsers must hold up against real optimized HLO, not just
    the synthetic lines above: compile the katana_bank op (interpret
    route — its jaxpr still lowers to a full XLA program) and check
    the census + cost_analysis wiring benchmarks/roofline.py relies
    on."""
    from benchmarks.common import compiled_of, hlo_cost
    from repro.core.filters import get_filter
    from repro.kernels.katana_bank.ops import katana_bank

    model = get_filter("lkf")
    N = 8
    rng = np.random.default_rng(1)
    x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, model.m)), jnp.float32)
    fn = lambda x, P, z: katana_bank(model, x, P, z, interpret=True)

    compiled = compiled_of(fn, x, P, z)
    census = op_census(compiled.as_text())
    assert all(isinstance(v, int) and v >= 0 for v in census.values())
    assert sum(census.values()) > 0  # a KF step is not op-free

    cost = hlo_cost(fn, x, P, z)
    assert cost["flops"] > 0
    assert cost["bytes"] > 0
    # a single-device program has no collectives
    assert totals(collective_census(compiled.as_text()))["count"] == 0
