"""Executable documentation: every ```python block in README.md and
docs/*.md is extracted and run, so the docs cannot rot. (Shell blocks
are fenced ```bash and skipped.) Runs in CI via the normal tier-1
pytest invocation."""
import re
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks():
    out = []
    for path in DOC_FILES:
        for i, m in enumerate(_BLOCK_RE.finditer(path.read_text())):
            out.append(pytest.param(
                path, m.group(1),
                id=f"{path.relative_to(ROOT)}#{i}"))
    return out


def test_docs_have_python_examples():
    """The docs subsystem ships runnable examples — at least one python
    block per documentation file set."""
    assert len(DOC_FILES) >= 4  # README + architecture/paper_mapping/benchmarks
    assert len(_blocks()) >= 4


@pytest.mark.parametrize("path,code", _blocks())
def test_docs_python_block_runs(path, code):
    src = str(ROOT / "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    # run from the repo root so relative paths (BENCH_*.json) resolve
    import os

    cwd = os.getcwd()
    os.chdir(ROOT)
    try:
        exec(compile(code, f"{path.name}:block", "exec"), {"__name__": "__docs__"})
    finally:
        os.chdir(cwd)
