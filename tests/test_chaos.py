"""Chaos suite: the streaming front end under injected faults
(serving/stream.py + serving/faults.py).

The acceptance criteria of the serving tentpole, verbatim:

  * kill a shard mid-run: its tenants fail over (checkpoint restore +
    WAL replay onto a surviving shard) and the resumed per-tenant
    FrameResult stream is BITWISE identical to an uninterrupted run,
    track ids preserved;
  * offer 2x sustained capacity: the front end walks the degradation
    ladder and sheds load with ZERO uncaught exceptions and no tenant
    starved;
  * sensor dropout: tracks coast, prune, and respawn cleanly when the
    sensor returns;
  * NaN/inf payloads never poison a bank; duplicates and clock skew
    are absorbed at admission.

Everything is driven by ``ChaosDriver`` on a fake clock — a failing
case replays exactly.
"""
import numpy as np
import pytest

import jax

from repro.core.filters import make_imm
from repro.core.tracker import TrackerConfig
from repro.serving.faults import ChaosDriver, FaultPlan, SkewedClock
from repro.serving.stream import (Admission, NS_STRIDE, StreamConfig,
                                  StreamFrontEnd)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


MODEL = make_imm()
TRACKER = TrackerConfig(capacity=8, max_meas=4)
TENANTS = ("alpha", "bravo", "charlie")


def walker_scene(tenant_seed, n_targets=2, m=3, drop_every=7):
    """Deterministic per-tenant random-walk targets; every
    ``drop_every``-th frame one detection goes missing."""
    rng = np.random.default_rng(tenant_seed)
    pos = rng.normal(scale=10.0, size=(n_targets, m)).astype(np.float32)
    steps = rng.normal(scale=0.3,
                       size=(256, n_targets, m)).astype(np.float32)

    def scene(i):
        z = pos + steps[: (i % 256) + 1].sum(0)
        if drop_every and i % drop_every == drop_every - 1:
            z = z[1:]
        return z

    return scene


def make_front(tmp_path, clk, tag, **kw):
    kw.setdefault("n_shards", 2)
    kw.setdefault("lanes_per_shard", 4)  # a survivor must be able to
    # absorb every tenant of a dead shard
    kw.setdefault("queue_depth", 8)
    kw.setdefault("checkpoint_every", 4)
    kw.setdefault("heartbeat_timeout_s", 1.0)
    # bitwise runs must stay at FULL tier while a dead shard's queues
    # back up, so the default thresholds are pushed out of reach
    kw.setdefault("degrade_at", 5.0)
    kw.setdefault("coast_at", 6.0)
    kw.setdefault("reject_at", 7.0)
    return StreamFrontEnd(MODEL, StreamConfig(**kw), TRACKER,
                          ckpt_dir=str(tmp_path / tag), clock=clk)


def drive(front, plan, cycles, dt=0.5, rate=1, budget=None):
    clk = front.clock
    scenes = {t: walker_scene(100 + i) for i, t in enumerate(TENANTS)}
    for t in TENANTS:
        assert front.attach(t) == Admission.ACCEPTED
    drv = ChaosDriver(front, plan, scenes, clk.advance, dt_s=dt,
                      deadline_budget_s=budget, offered_rate=rate)
    rep = drv.run(cycles)
    # drain the backlog a dead period left behind (updates keep
    # accumulating so streams can be compared end-to-end)
    for _ in range(40):
        ups = front.pump()
        if not ups:
            break
        for t, u in ups.items():
            rep.updates[t].append(u)
        clk.advance(dt)
    return rep


def assert_streams_bitwise(ref, got):
    """Per-tenant update streams must match frame-for-frame: same
    kinds, same seqs, same track ids, bitwise-identical states."""
    for t in TENANTS:
        ru, gu = ref.updates[t], got.updates[t]
        assert len(ru) == len(gu), \
            f"{t}: {len(gu)} frames applied vs {len(ru)} uninterrupted"
        for r, g in zip(ru, gu):
            assert (r.frame, r.seq, r.kind) == (g.frame, g.seq, g.kind)
            assert len(r.snapshots) == len(g.snapshots), \
                f"{t} frame {r.frame}: track count diverged"
            for rs, gs in zip(r.snapshots, g.snapshots):
                assert rs.track_id == gs.track_id
                assert (rs.hits, rs.age) == (gs.hits, gs.age)
                np.testing.assert_array_equal(rs.state, gs.state)
                np.testing.assert_array_equal(rs.mode_probs,
                                              gs.mode_probs)


# ---------------------------------------------------------------- failover
class TestFailover:
    def test_shard_kill_resumes_bitwise(self, tmp_path):
        """THE acceptance test: kill the shard under two tenants
        mid-run; the failed-over streams are bitwise identical to an
        uninterrupted run, ids preserved."""
        clk_ref = FakeClock()
        ref_front = make_front(tmp_path, clk_ref, "ref")
        ref = drive(ref_front, FaultPlan(), cycles=16)
        assert not ref.exceptions

        clk = FakeClock()
        front = make_front(tmp_path, clk, "chaos")
        got = drive(front, FaultPlan(kill_shards={7: 0}), cycles=16)
        assert got.exceptions == []
        assert front.stats.shards_lost == 1
        assert front.stats.failovers > 0
        assert "shard0" in got.killed_at
        assert got.recovered_at, "no tenant ever recovered"
        assert_streams_bitwise(ref, got)
        # the dead shard is gone for good
        assert front.shards_alive() == ["shard1"]

    def test_failover_with_stale_checkpoint_replays_long_wal(
            self, tmp_path):
        """checkpoint_every larger than the run: failover must rebuild
        the whole lane from the frame-0 snapshot + full WAL replay —
        still bitwise."""
        clk_ref = FakeClock()
        ref = drive(make_front(tmp_path, clk_ref, "ref",
                               checkpoint_every=1000),
                    FaultPlan(), cycles=12)
        clk = FakeClock()
        front = make_front(tmp_path, clk, "chaos", checkpoint_every=1000)
        got = drive(front, FaultPlan(kill_shards={6: 0}), cycles=12)
        assert got.exceptions == []
        assert_streams_bitwise(ref, got)

    def test_track_ids_keep_their_namespace_across_failover(
            self, tmp_path):
        clk = FakeClock()
        front = make_front(tmp_path, clk, "ns")
        got = drive(front, FaultPlan(kill_shards={7: 0}), cycles=16)
        assert got.exceptions == []
        for i, t in enumerate(TENANTS):
            ns = front.tenants[t].ns_base
            assert ns == i * NS_STRIDE  # attach order pins the base
            for u in got.updates[t]:
                for s in u.snapshots:
                    assert s.track_id // NS_STRIDE == i

    def test_second_kill_parks_when_no_lanes_survive(self, tmp_path):
        clk = FakeClock()
        front = make_front(tmp_path, clk, "park", lanes_per_shard=2)
        with pytest.warns(RuntimeWarning, match="parked"):
            got = drive(front, FaultPlan(kill_shards={5: 0, 10: 1}),
                        cycles=16)
        assert got.exceptions == []
        assert front.shards_alive() == []
        assert front.stats.parked > 0


# ---------------------------------------------------------------- overload
class TestOverload:
    def test_2x_capacity_sheds_via_ladder_no_starvation(self, tmp_path):
        """Twice the sustainable load: the ladder engages, shedding is
        explicit, nothing raises, every tenant keeps being served."""
        clk = FakeClock()
        front = make_front(tmp_path, clk, "load", queue_depth=4,
                           degrade_at=0.375, coast_at=0.8,
                           reject_at=0.95)
        got = drive(front, FaultPlan(), cycles=24, rate=2)
        assert got.exceptions == []
        s = front.stats
        # overload was actually shed, through the ladder and admission
        shed_total = (s.shed + s.replaced_oldest + s.rejected_overload
                      + s.rejected_queue_full)
        assert shed_total > 0, "2x load but nothing was shed"
        assert s.accepted < s.submitted
        # no tenant starves: everyone keeps a live stream, and the
        # anti-starvation floor bounds every coast streak
        for t in TENANTS:
            assert got.frames_applied(t) >= 12
            assert got.served_fraction(t) > 0.15
            streak, longest = 0, 0
            for u in got.updates[t]:
                streak = streak + 1 if u.kind == "shed" else 0
                longest = max(longest, streak)
            assert longest <= front.cfg.starve_limit
        # and the ladder was the mechanism, not luck
        decisions = {d for dec in got.decisions.values()
                     for _, d in dec}
        assert decisions & {Admission.REJECTED_OVERLOAD,
                            Admission.REPLACED_OLDEST}

    def test_recovers_to_full_tier_when_load_drops(self, tmp_path):
        clk = FakeClock()
        front = make_front(tmp_path, clk, "recover", queue_depth=4,
                           degrade_at=0.375, coast_at=0.8,
                           reject_at=0.95)
        drive(front, FaultPlan(), cycles=12, rate=2)
        # backlog drained by drive(); offered load is now zero
        from repro.serving.stream import ServiceTier
        assert front.effective_tier() == ServiceTier.FULL


# ----------------------------------------------------------- sensor faults
class TestSensorFaults:
    def test_dropout_coasts_prunes_respawns(self, tmp_path):
        clk = FakeClock()
        front = make_front(tmp_path, clk, "dropout")
        plan = FaultPlan(dropouts={"alpha": (8, 16)})
        got = drive(front, plan, cycles=24)
        assert got.exceptions == []
        ups = got.updates["alpha"]
        kinds = [u.kind for u in ups]
        assert kinds[8:16] == ["coast"] * 8
        # confirmed tracks before the window, none by its end (pruned),
        # respawned after the sensor comes back
        assert len(ups[7].snapshots) > 0
        assert len(ups[15].snapshots) == 0
        assert len(ups[-1].snapshots) > 0
        # the other tenants never noticed
        assert all(u.kind == "served" for u in got.updates["bravo"])

    def test_nan_inf_bursts_never_poison_the_banks(self, tmp_path):
        clk = FakeClock()
        front = make_front(tmp_path, clk, "nan")
        plan = FaultPlan(corruptions={("alpha", c): ("nan" if c % 2
                                                     else "inf")
                                      for c in range(4, 12)})
        got = drive(front, plan, cycles=16)
        assert got.exceptions == []
        for sh in front.shards:
            if sh.alive:
                assert np.isfinite(np.asarray(sh.banks.x)).all()
                assert np.isfinite(np.asarray(sh.banks.P)).all()
        # the corrupted tenant still has a live, finite stream
        for u in got.updates["alpha"]:
            for s in u.snapshots:
                assert np.isfinite(s.state).all()

    def test_duplicates_are_dropped_and_change_nothing(self, tmp_path):
        clk_ref = FakeClock()
        ref = drive(make_front(tmp_path, clk_ref, "ref"), FaultPlan(),
                    cycles=12)
        clk = FakeClock()
        front = make_front(tmp_path, clk, "dup")
        plan = FaultPlan(duplicates=tuple(("alpha", c)
                                          for c in range(3, 9)))
        got = drive(front, plan, cycles=12)
        assert got.exceptions == []
        assert front.stats.duplicates == 6
        assert_streams_bitwise(ref, got)

    def test_clock_skew_expires_only_the_skewed_tenant(self, tmp_path):
        clk = FakeClock(t=100.0)
        front = make_front(tmp_path, clk, "skew")
        # alpha's clock is 10s behind: its deadlines are already past
        plan = FaultPlan(skews_s={"alpha": -10.0})
        got = drive(front, plan, cycles=12, budget=2.0)
        assert got.exceptions == []
        assert front.stats.expired > 0
        assert got.frames_applied("alpha") == 0  # all pre-expired
        for t in ("bravo", "charlie"):
            assert got.frames_applied(t) == 12  # untouched


# ------------------------------------------------------------ the kitchen sink
def test_everything_at_once(tmp_path):
    """All fault classes in one run: still zero uncaught exceptions and
    every un-parked tenant keeps a stream."""
    clk = FakeClock(t=50.0)
    front = make_front(tmp_path, clk, "sink", queue_depth=6,
                       degrade_at=0.4, coast_at=0.7, reject_at=0.95)
    plan = FaultPlan(
        kill_shards={9: 0},
        dropouts={"bravo": (4, 8)},
        corruptions={("charlie", 5): "nan", ("charlie", 6): "inf"},
        duplicates=(("alpha", 3), ("bravo", 11)),
        skews_s={"charlie": 0.5},
    )
    got = drive(front, plan, cycles=20, rate=2, budget=30.0)
    assert got.exceptions == []
    assert front.stats.shards_lost == 1
    for t in TENANTS:
        assert got.frames_applied(t) > 0
    for sh in front.shards:
        if sh.alive:
            assert np.isfinite(np.asarray(sh.banks.x)).all()


# --------------------------------------------------------- device placement
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (CI forces 8)")
def test_shards_pin_to_distinct_devices_and_failover_migrates(tmp_path):
    clk = FakeClock()
    devs = jax.devices()[:2]
    front = StreamFrontEnd(MODEL,
                           StreamConfig(n_shards=2, lanes_per_shard=4,
                                        degrade_at=5.0, coast_at=6.0,
                                        reject_at=7.0),
                           TRACKER, ckpt_dir=str(tmp_path),
                           clock=clk, devices=devs)
    assert front.shards[0].device != front.shards[1].device
    for sh in front.shards:
        assert next(iter(sh.banks.x.devices())) == sh.device
    got = drive(front, FaultPlan(kill_shards={5: 0}), cycles=12)
    assert got.exceptions == []
    survivor = front.shards[1]
    # every migrated tenant's lane lives on the survivor's device now
    assert next(iter(survivor.banks.x.devices())) == survivor.device
    for t in TENANTS:
        assert front.tenants[t].shard == 1
