"""All attention lowerings (full / chunked / swa / flash kernel) are the
same function; decode against a prefix-built cache matches full
attention on the extended sequence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig
from repro.models import attention as A


def _setup(S=128, B=2, H=4, K=2, hd=16, window=None, causal=True, seed=0):
    acfg = AttentionConfig(n_heads=H, n_kv_heads=K, head_dim=hd,
                           causal=causal, sliding_window=window)
    d = 32
    key = jax.random.key(seed)
    p = A.attn_init(key, acfg, d, jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (B, S, d), jnp.float32)
    pos = jnp.arange(S)
    return acfg, p, x, pos


@pytest.mark.parametrize("impl", ["chunked", "flash"])
@pytest.mark.parametrize("window", [None, 32])
def test_impls_match_full(impl, window):
    acfg, p, x, pos = _setup(window=window)
    out_full, _ = A.apply_attention(p, x, acfg, pos, "train", impl="full")
    out_other, _ = A.apply_attention(p, x, acfg, pos, "train", impl=impl,
                                     q_chunk=32)
    np.testing.assert_allclose(np.asarray(out_other), np.asarray(out_full),
                               atol=2e-5, rtol=2e-4)


def test_swa_banded_matches_full():
    acfg, p, x, pos = _setup(S=256, window=32)
    out_full, _ = A.apply_attention(p, x, acfg, pos, "train", impl="full")
    out_swa, _ = A.apply_attention(p, x, acfg, pos, "train", impl="swa",
                                   q_chunk=32)
    np.testing.assert_allclose(np.asarray(out_swa), np.asarray(out_full),
                               atol=2e-5, rtol=2e-4)


def test_decode_matches_full_attention():
    """prefill S tokens -> decode token S: logits column == full
    attention over S+1 tokens at the last position."""
    acfg, p, x, pos = _setup(S=64)
    B, S, d = x.shape
    x_next = jax.random.normal(jax.random.key(9), (B, 1, d), jnp.float32)
    # full attention over the extended sequence
    x_ext = jnp.concatenate([x, x_next], axis=1)
    out_ext, _ = A.apply_attention(p, x_ext, acfg, jnp.arange(S + 1),
                                   "train", impl="full")
    want = out_ext[:, -1:]
    # prefill + decode path
    _, cache = A.apply_attention(p, x, acfg, pos, "prefill", impl="full")
    got, new_cache = A.apply_attention(
        p, x_next, acfg, jnp.asarray([S]), "decode", cache=cache,
        cache_pos=jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)
    assert new_cache.k.shape == cache.k.shape  # ring buffer, no growth
