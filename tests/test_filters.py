"""Filter-model construction + float64 oracle sanity."""
import numpy as np
import pytest

from repro.core import ref
from repro.core.filters import get_filter, make_ctra_ekf, make_cv_lkf
from repro.data.trajectories import single_target


@pytest.mark.parametrize("kind,n,m", [("lkf", 6, 3), ("ekf", 8, 4)])
def test_dims_match_paper(kind, n, m):
    model = get_filter(kind)
    assert model.n == n and model.m == m  # paper §V workload dims
    assert model.F.shape == (n, n)
    assert model.H.shape == (m, n)
    assert model.Q.shape == (n, n)
    assert model.R.shape == (m, m)


def test_lkf_cv_structure():
    model = make_cv_lkf(dt=0.1)
    np.testing.assert_allclose(model.F[:3, 3:], 0.1 * np.eye(3))
    np.testing.assert_allclose(model.H[:, :3], np.eye(3))


def test_ekf_jacobian_matches_fd():
    """Analytic Jacobian == finite differences of f (numpy mirror)."""
    model = make_ctra_ekf(dt=0.05)
    rng = np.random.default_rng(0)
    for _ in range(5):
        x = rng.normal(size=model.n)
        J = model.F_jac_np(x)
        eps = 1e-6
        fd = np.zeros_like(J)
        for j in range(model.n):
            dx = np.zeros(model.n)
            dx[j] = eps
            fd[:, j] = (model.f_np(x + dx) - model.f_np(x - dx)) / (2 * eps)
        np.testing.assert_allclose(J, fd, atol=1e-6)


def test_ekf_jnp_matches_np():
    import jax.numpy as jnp

    model = make_ctra_ekf()
    rng = np.random.default_rng(1)
    x = rng.normal(size=(4, model.n)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(model.f(jnp.asarray(x))),
        np.stack([model.f_np(xi) for xi in x]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(model.jacobian(jnp.asarray(x))),
        np.stack([model.F_jac_np(xi) for xi in x]), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["lkf", "ekf"])
def test_oracle_reduces_error(kind):
    """The oracle filter beats raw measurements on its own dynamics."""
    model = get_filter(kind)
    truth, zs = single_target(model, 300, seed=3)
    est, covs = ref.run(model, zs)
    pos = slice(0, 3)
    rmse_meas = np.sqrt(np.mean((zs[:, :3] - truth[:, pos]) ** 2))
    rmse_filt = np.sqrt(np.mean((est[100:, pos] - truth[100:, pos]) ** 2))
    assert rmse_filt < rmse_meas
    # covariance stays symmetric PSD
    for P in covs[::50]:
        np.testing.assert_allclose(P, P.T, atol=1e-12)
        assert np.linalg.eigvalsh(P).min() > -1e-10
