"""Per-architecture smoke tests: a reduced config of the same family
runs one forward/train step (and prefill+decode where applicable) on
CPU, asserting output shapes and no NaNs.  (Deliverable f.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import cells_for, get_config, list_archs, reduced
from repro.models import blocks, model as model_lib

SEQ = 32
BATCH = 2


def make_batch(cfg, key, mode="train"):
    k1, k2 = jax.random.split(key)
    batch = {}
    n_front = cfg.frontend_positions
    if cfg.frontend == "audio":
        n_front = SEQ  # every position comes from the audio frontend
    s_text = SEQ - n_front
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(
            k1, (BATCH, n_front, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if s_text > 0:
        batch["tokens"] = jax.random.randint(k2, (BATCH, s_text), 0, cfg.vocab)
    if mode == "train":
        batch["labels"] = jax.random.randint(k2, (BATCH, SEQ), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module")
def arch_params():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch), seq=SEQ)
            params = model_lib.init_params(cfg, jax.random.key(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch, arch_params):
    cfg, params = arch_params(arch)
    batch = make_batch(cfg, jax.random.key(1))
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(
            lambda p: model_lib.loss_fn(p, cfg, batch), has_aux=True)
    )(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert np.isfinite(float(metrics["ce"]))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in leaves)
    # at least one grad leaf is non-zero (the model actually trains)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes(arch, arch_params):
    cfg, params = arch_params(arch)
    batch = make_batch(cfg, jax.random.key(2), mode="prefill")
    logits, caches, aux = jax.jit(
        lambda p, b: model_lib.forward(p, cfg, b, "prefill"))(params, batch)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    if cfg.is_encoder_only:
        return
    assert caches is not None


@pytest.mark.parametrize("arch",
                         [a for a in list_archs()
                          if not get_config(a).is_encoder_only])
def test_prefill_then_decode(arch, arch_params):
    """Decode consumes the prefill cache and emits finite logits."""
    cfg, params = arch_params(arch)
    batch = make_batch(cfg, jax.random.key(3), mode="prefill")
    _, caches, _ = jax.jit(
        lambda p, b: model_lib.forward(p, cfg, b, "prefill"))(params, batch)
    step = {"token": jnp.ones((BATCH, 1), jnp.int32),
            "cache_pos": jnp.asarray(SEQ, jnp.int32)}
    logits, new_caches, _ = jax.jit(
        lambda p, b, c: model_lib.forward(p, cfg, b, "decode", caches=c)
    )(params, step, caches)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # caches keep their shapes (ring-buffer discipline, no growth)
    jax.tree.map(lambda a, b: (a.shape == b.shape) or
                 (_ for _ in ()).throw(AssertionError((a.shape, b.shape))),
                 caches, new_caches)


def test_cell_skip_rules():
    """The (arch x shape) support matrix matches DESIGN.md §6."""
    skips = {}
    for arch in list_archs():
        cfg = get_config(arch)
        skips[arch] = [s.name for (s, ok, _) in cells_for(cfg) if not ok]
    assert skips["hubert-xlarge"] == ["decode_32k", "long_500k"]
    assert skips["mamba2-130m"] == []
    assert skips["jamba-1.5-large-398b"] == []
    assert skips["h2o-danube-1.8b"] == []  # SWA => sub-quadratic
    for dense_arch in ("command-r-35b", "granite-20b", "nemotron-4-15b",
                       "internvl2-2b", "qwen3-moe-235b-a22b",
                       "granite-moe-1b-a400m"):
        assert skips[dense_arch] == ["long_500k"], dense_arch
