"""Substrate tests: data determinism, optimizer, checkpoint/restart
(incl. crash-restart + elastic reshard), FT monitors, compression,
serving engine."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import RunConfig, get_config, reduced
from repro.data.lm import LMDataPipeline
from repro.distributed.compression import ef_compress
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_train_step
from repro.models import model as model_lib
from repro.optim import adamw
from repro.runtime.ft import (HeartbeatMonitor, StragglerDetector,
                              TrainSupervisor)
from repro.sharding.rules import ShardingContext


def test_data_pipeline_deterministic_and_resumable():
    p1 = LMDataPipeline(256, 32, 4, seed=7)
    b1 = [p1.next_batch() for _ in range(3)]
    p2 = LMDataPipeline(256, 32, 4, seed=7)
    p2.load_state_dict({"step": 2})
    np.testing.assert_array_equal(p2.next_batch()["tokens"],
                                  b1[2]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["labels"][:, :-1],
                                  b1[0]["tokens"][:, 1:])


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init_train_state(params)
    for _ in range(300):
        g = {"w": 2 * state.master["w"]}
        state = adamw.adamw_update(state, g, 0.05, weight_decay=0.0)
    assert float(jnp.abs(state.master["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_ef_compress_preserves_signal():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    ef = {"w": jnp.zeros((64,), jnp.float32)}
    # accumulated dequantized grads converge to accumulated true grads
    acc_true = np.zeros(64)
    acc_deq = np.zeros(64)
    for _ in range(30):
        deq, ef = ef_compress(g, ef)
        acc_true += np.asarray(g["w"])
        acc_deq += np.asarray(deq["w"])
    rel = np.abs(acc_deq - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.02  # error feedback keeps the long-run estimate tight


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.asarray(3, jnp.int32)}}
    ckpt_lib.save(str(tmp_path), 5, state, {"note": "hi"})
    like = jax.tree.map(lambda x: np.zeros_like(x), state)
    restored, extra = ckpt_lib.restore(str(tmp_path), like)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert extra["note"] == "hi"


def test_checkpoint_manager_keep_n_and_async(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep_n=2)
    state = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, {"step": s})
    mgr.wait()
    assert ckpt_lib.available_steps(str(tmp_path)) == [3, 4]


def test_elastic_restore_onto_new_mesh(tmp_path):
    """Save unsharded, restore sharded onto a small mesh (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = {"w": jnp.arange(16, dtype=jnp.float32)}
    ckpt_lib.save(str(tmp_path), 1, state)
    mesh = mesh_lib.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = ckpt_lib.restore(str(tmp_path), state, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16, dtype=np.float32))


def test_train_step_decreases_loss_and_resumes(tmp_path):
    """Real train loop on a reduced arch: loss decreases; a crash mid-
    run restores from checkpoint and converges to the same stream."""
    cfg = reduced(get_config("granite-moe-1b-a400m"), n_layers=2,
                  d_model=64, vocab=64, seq=32)
    run = RunConfig(microbatches=2, learning_rate=3e-3, warmup_steps=5,
                    total_steps=40, remat="none")
    params = model_lib.init_params(cfg, jax.random.key(0))
    state = adamw.init_train_state(params)
    data = LMDataPipeline(cfg.vocab, 32, 8, seed=1, microbatches=2)
    step_fn = jax.jit(make_train_step(cfg, run, ShardingContext(None)))
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep_n=2)

    holder = {"state": state}
    losses = []
    crash_at = 12

    def one_step(i):
        if i == crash_at and not one_step.crashed:
            one_step.crashed = True
            raise RuntimeError("induced host failure")
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        holder["state"], m = step_fn(holder["state"], batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 5 == 0:
            mgr.save(i + 1, holder["state"],
                     {"step": i + 1, "data": data.state_dict()},
                     blocking=True)

    one_step.crashed = False

    def restore():
        holder["state"], extra = mgr.restore_latest(holder["state"])
        data.load_state_dict(extra["data"])
        return int(extra["step"])

    sup = TrainSupervisor(one_step, restore, 25, max_restarts=2)
    report = sup.run()
    assert report.restarts == 1
    assert report.restored_steps == [10]
    assert losses[-1] < losses[0]  # it actually learns
    assert int(holder["state"].step) >= 25


def test_heartbeat_and_straggler():
    t = {"now": 0.0}
    hb = HeartbeatMonitor(["h0", "h1"], timeout_s=5.0,
                          clock=lambda: t["now"])
    t["now"] = 3.0
    hb.beat("h0")
    t["now"] = 7.0
    assert hb.dead_hosts() == ["h1"]

    sd = StragglerDetector(["h0", "h1", "h2"], k=2.0)
    for _ in range(5):
        sd.record("h0", 1.0)
        sd.record("h1", 1.1)
        sd.record("h2", 5.0)
    assert sd.stragglers() == ["h2"]


def test_tracking_engine_serves():
    from repro.core.filters import get_filter
    from repro.serving.engine import TrackingEngine
    from repro.core.tracker import TrackerConfig

    model = get_filter("lkf")
    eng = TrackingEngine(model, TrackerConfig(capacity=16, max_meas=8))
    rng = np.random.default_rng(0)
    pos = rng.normal(size=(2, 3)) * 5
    for _ in range(6):
        pos = pos + 0.05
        tracks = eng.submit(pos + rng.normal(size=pos.shape) * 0.05)
    assert len(tracks) == 2
    assert eng.stats.frames == 6
    assert eng.stats.fps > 0


def test_compressed_psum_ring():
    """int8 ring all-reduce == fp32 psum within quantization tolerance,
    and the HLO wire payload is s8."""
    import re
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compression import compressed_psum

    mesh = mesh_lib.make_mesh((1,), ("pod",))

    def f(x):
        return compressed_psum(x, "pod")

    from repro import compat

    sharded = compat.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                               check=False)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                    jnp.float32)
    out = sharded(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=2e-2,
                               rtol=2e-2)
