"""Execution-mode resolution: env plumbing, loud fallback, row labels.

The contract under test (src/repro/execmode.py): a single resolver
decides interpret-vs-compiled for every kernel op; a ``compiled``
request on a backend that can't lower Pallas falls back LOUDLY
(``ExecModeFallbackWarning`` + non-None ``fallback``); per-BENCH-row
labels call XLA-native paths compiled everywhere but Pallas paths
compiled only when natively lowered. The CI compiled-mode job relies
on every one of these properties.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.execmode import (ENV_VAR, ExecMode, ExecModeFallbackWarning,
                            active_mode, pallas_lowering_supported,
                            resolve_interpret, resolve_mode)


def test_auto_resolves_to_backend_capability():
    m = resolve_mode("auto")
    assert m.requested == "auto"
    assert m.backend == jax.default_backend()
    assert m.pallas_native == pallas_lowering_supported(m.backend)
    # auto never warns and never records a fallback
    assert m.fallback is None
    assert m.mode == ("compiled" if m.pallas_native else "interpret")


def test_interpret_request_is_always_honored():
    m = resolve_mode("interpret")
    assert m.mode == "interpret"
    assert m.interpret is True
    assert m.fallback is None


def test_compiled_request_is_never_silent():
    """compiled either really compiles or records a loud fallback —
    there is no third state. (_resolve is lru_cached, so the warning
    fires once per process: clear the cache to observe it here.)"""
    from repro.execmode import _resolve

    _resolve.cache_clear()
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            m = resolve_mode("compiled")
        fired = [w for w in caught
                 if issubclass(w.category, ExecModeFallbackWarning)]
        if m.pallas_native:
            assert m.mode == "compiled"
            assert m.fallback is None
            assert not fired
        else:
            assert m.mode == "interpret"
            assert m.fallback == f"pallas-lowering-unsupported:{m.backend}"
            assert fired, "fallback must warn loudly"
    finally:
        _resolve.cache_clear()  # order-independence for other tests


def test_env_var_drives_active_mode(monkeypatch):
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert active_mode().requested == "interpret"
    monkeypatch.setenv(ENV_VAR, "AUTO")  # case/space tolerant
    assert active_mode().requested == "auto"
    monkeypatch.delenv(ENV_VAR)
    assert active_mode().requested == "auto"


def test_bad_mode_rejected(monkeypatch):
    monkeypatch.setenv(ENV_VAR, "turbo")
    with pytest.raises(ValueError, match="turbo"):
        active_mode()


def test_explicit_interpret_beats_mode():
    """Tests pin the interpreter with interpret=True regardless of the
    requested mode — the ops-level shim must honor that."""
    assert resolve_interpret(True, mode="interpret") is True
    assert resolve_interpret(False, mode="interpret") is False
    assert resolve_interpret(None, mode="interpret") is True


def test_row_labels_are_honest():
    """XLA rows are compiled everywhere; Pallas rows are compiled only
    when the kernel itself lowered natively."""
    native = ExecMode("compiled", "compiled", "tpu", True, None, "x")
    fell_back = ExecMode("compiled", "interpret", "cpu", False,
                         "pallas-lowering-unsupported:cpu", "x")
    assert native.lowering(pallas=True) == "pallas"
    assert native.row_mode(pallas=True) == "compiled"
    assert fell_back.lowering(pallas=True) == "pallas-interpret"
    assert fell_back.row_mode(pallas=True) == "interpret"
    for m in (native, fell_back):
        assert m.lowering(pallas=False) == "xla"
        assert m.row_mode(pallas=False) == "compiled"


def test_as_meta_round_trips_the_facts():
    m = resolve_mode("auto")
    meta = m.as_meta()
    assert meta["backend"] == m.backend
    assert meta["mode"] == m.mode
    assert meta["requested"] == "auto"
    assert meta["jax"] == jax.__version__
    assert meta["fallback"] is None


def test_ops_honor_resolved_mode(monkeypatch):
    """End-to-end: KATANA_MODE threads env -> resolver -> ops wrapper
    -> pallas_call, and the result is unchanged (same math, different
    dispatch route is only possible where the backend lowers Pallas)."""
    import jax.numpy as jnp

    from repro.core.filters import get_filter
    from repro.kernels.katana_bank.ops import katana_bank

    model = get_filter("lkf")
    N = 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
    P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, model.m)), jnp.float32)

    x_pinned, P_pinned = katana_bank(model, x, P, z, interpret=True)
    monkeypatch.setenv(ENV_VAR, "compiled")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", ExecModeFallbackWarning)
        x_env, P_env = katana_bank(model, x, P, z)
    np.testing.assert_allclose(np.asarray(x_env), np.asarray(x_pinned),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(P_env), np.asarray(P_pinned),
                               atol=1e-5, rtol=1e-5)


def test_tracker_config_carries_mode():
    from repro.core.tracker import TrackerConfig

    m = TrackerConfig(capacity=8, max_meas=4, mode="interpret").exec_mode()
    assert m.requested == "interpret" and m.interpret
    # default config defers to the environment resolver
    assert TrackerConfig(capacity=8, max_meas=4).exec_mode() == active_mode()
