"""End-to-end MOT walkthrough: maneuvering scene -> TrackingEngine ->
confirmed tracks with IMM mode probabilities.

Three maneuvering targets (CV / coordinated-turn / acceleration segment
switching) are detected with noise each frame and fed to an IMM
TrackingEngine. The demo prints the confirmed track table every 20
frames — watch the mode probabilities shift between CV / CA / CT(+w) /
CT(-w) as each target maneuvers — and compares the final IMM position
error against a single-model CV engine on the same detections.

Referenced from docs/architecture.md.

  PYTHONPATH=src python examples/mot_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core.filters import get_filter, make_imm  # noqa: E402
from repro.core.tracker import TrackerConfig  # noqa: E402
from repro.data.trajectories import maneuvering_batch  # noqa: E402
from repro.serving.engine import TrackingEngine  # noqa: E402

MODE_NAMES = ("CV", "CA", "CT+", "CT-")


def final_position_error(snaps, truth_t):
    """Mean distance from each confirmed track to its nearest truth."""
    if not snaps:
        return float("nan")
    est = np.stack([s.state[:3] for s in snaps])
    d = np.linalg.norm(est[:, None] - truth_t[None, :, :3], axis=-1)
    return float(d.min(axis=1).mean())


def main():
    T, N = 120, 3
    truth, zs = maneuvering_batch(T, N, seed=11)
    cfg = TrackerConfig(capacity=16, max_meas=8, min_hits=3)

    imm_engine = TrackingEngine(make_imm(), cfg)
    cv_engine = TrackingEngine(get_filter("lkf"), cfg)

    print(f"scene: {N} maneuvering targets, {T} frames "
          f"(segments switch between CV / turns / acceleration)\n")
    for t in range(T):
        snaps = imm_engine.submit(zs[t])
        cv_snaps = cv_engine.submit(zs[t])
        if (t + 1) % 20 == 0:
            print(f"frame {t + 1:3d}: {len(snaps)} confirmed IMM tracks")
            for s in snaps:
                modes = " ".join(f"{name}={p:.2f}" for name, p in
                                 zip(MODE_NAMES, s.mode_probs))
                px, py, pz = s.state[:3]
                print(f"  track {s.track_id}: pos=({px:+6.2f},{py:+6.2f},"
                      f"{pz:+6.2f}) hits={s.hits:3d}  {modes}")

    err_imm = final_position_error(snaps, truth[-1])
    err_cv = final_position_error(cv_snaps, truth[-1])
    print(f"\nfinal mean position error: IMM {err_imm:.3f} vs "
          f"single-model CV {err_cv:.3f}")
    print(f"IMM engine fps (jitted frame steps): "
          f"{imm_engine.stats.fps:.1f}")


if __name__ == "__main__":
    main()
