"""End-to-end MOT serving driver — the paper's Fig. 5 scenario.

A (stub) detector produces noisy bounding-box centroids per frame for a
scene with target births/deaths and clutter; the KATANA TrackingEngine
(one jitted frame step: predict -> gate -> greedy associate -> update ->
spawn -> prune) maintains the track table. Reports throughput and
MOTA-style counts — the serving analogue of the paper's live-video demo.

  PYTHONPATH=src python examples/tracking_pipeline.py --filter ekf
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core.filters import get_filter  # noqa: E402
from repro.core.tracker import TrackerConfig  # noqa: E402
from repro.data.trajectories import SceneConfig, mot_scene  # noqa: E402
from repro.serving.engine import TrackingEngine  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--filter", default="lkf", choices=["lkf", "ekf"])
    ap.add_argument("--frames", type=int, default=150)
    ap.add_argument("--targets", type=int, default=6)
    ap.add_argument("--clutter", type=float, default=1.0)
    args = ap.parse_args()

    model = get_filter(args.filter)
    engine = TrackingEngine(model, TrackerConfig(capacity=64, max_meas=32))
    scene = SceneConfig(T=args.frames, max_targets=args.targets,
                        clutter_rate=args.clutter, max_meas=32)
    z, valid, truth = mot_scene(model, scene, seed=3)

    errs = []
    count_err = []
    for t in range(scene.T):
        k = int(valid[t].sum())
        tracks = engine.submit(z[t][valid[t]][:k])
        n_true = len(truth[t])
        count_err.append(abs(len(tracks) - n_true))
        # localization error of matched (nearest) tracks
        for tid, xt in truth[t]:
            if tracks:
                d = min(np.linalg.norm(tr.state[:3] - xt[:3])
                        for tr in tracks)
                errs.append(d)
    fps = engine.stats.fps
    print(f"filter={args.filter} frames={scene.T} "
          f"throughput={fps:.1f} FPS ({1e3 / fps:.2f} ms/frame)")
    print(f"mean count error (last 50 frames): "
          f"{np.mean(count_err[-50:]):.2f}")
    print(f"mean localization error (matched): {np.mean(errs):.3f} "
          f"(measurement noise sigma ~{np.sqrt(model.R[0, 0]):.3f})")
    frame_budget_pct = 100.0 * (1.0 / fps) / (1.0 / 30.0)
    print(f"tracker consumes {frame_budget_pct:.1f}% of a 30 FPS frame "
          f"budget (paper: <1% on the NPU)")


if __name__ == "__main__":
    main()
