"""Quickstart: KATANA in five minutes.

1. Build the paper's two filters (LKF cv-6, EKF ctra-8).
2. Run all four rewrite stages over the same measurement stream and
   verify they produce the same track (the rewrites are exact).
3. Run the fused Pallas kernel (katana_bank) over a 200-filter bank —
   the paper's batched configuration — and compare against the oracle.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ref  # noqa: E402
from repro.core.filters import get_filter  # noqa: E402
from repro.core.rewrites import STAGES, run_sequence  # noqa: E402
from repro.data.trajectories import batched_targets, single_target  # noqa: E402
from repro.kernels.katana_bank.ops import katana_bank  # noqa: E402


def main():
    for kind in ("lkf", "ekf"):
        model = get_filter(kind)
        print(f"\n=== {model.name} (n={model.n}, m={model.m}) ===")
        truth, zs = single_target(model, 150, seed=0)
        est, _ = ref.run(model, zs)
        rmse_meas = np.sqrt(np.mean((zs[:, :3] - truth[:, :3]) ** 2))
        rmse_filt = np.sqrt(np.mean((est[30:, :3] - truth[30:, :3]) ** 2))
        print(f"measurement rmse {rmse_meas:.4f} -> filtered {rmse_filt:.4f}")

        x0 = np.tile(model.x0, (1, 1))
        P0 = np.tile(model.P0, (1, 1, 1))
        for stage in STAGES:
            N = 1 if stage in ("baseline", "opt1", "opt2") else 1
            got = np.asarray(run_sequence(model, stage, zs[:, None, :],
                                          x0, P0))[:, 0]
            dev = np.max(np.abs(got - est))
            print(f"  stage {stage:20s} max deviation vs oracle {dev:.2e}")

        # batched bank through the fused Pallas kernel (N=200, paper cfg)
        N = 200
        truthN, zsN = batched_targets(model, 20, N, seed=1)
        x = jnp.asarray(np.tile(model.x0, (N, 1)), jnp.float32)
        P = jnp.asarray(np.tile(model.P0, (N, 1, 1)), jnp.float32)
        for t in range(20):
            x, P = katana_bank(model, x, P, jnp.asarray(zsN[t], jnp.float32))
        want, _, _ = ref.run_batched(model, zsN, np.tile(model.x0, (N, 1)),
                                     np.tile(model.P0, (N, 1, 1)))
        print(f"  katana_bank kernel (N={N}) max dev vs float64 oracle: "
              f"{np.max(np.abs(np.asarray(x) - want[-1])):.2e}")


if __name__ == "__main__":
    main()
