"""Train a reduced LM (any of the 10 assigned archs) end-to-end on CPU:
data pipeline -> microbatched AdamW train loop -> async checkpoints ->
crash-restart supervisor. A few hundred steps drive the loss visibly
down on the synthetic stream.

  PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --steps 200
  PYTHONPATH=src python examples/train_lm.py --arch granite-moe-1b-a400m \
      --steps 150 --grad-compression
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--reduced" not in argv:
        argv.append("--reduced")
    losses = main(argv)
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
