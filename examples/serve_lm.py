"""LM serving demo: batched prefill -> autoregressive decode with the
ring KV/SSM caches, on a reduced config of any assigned arch.

Greedy-decodes continuations for a batch of prompts from the synthetic
stream; reports prefill and per-token decode latency. The same
prefill/decode steps are what the dry-run lowers onto the production
meshes (with seq-sharded caches — see EXPERIMENTS.md §Perf cell 2).

  PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-1.8b \
      --prompt-len 64 --gen 32
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.launch.steps import make_decode_step, make_prefill_step  # noqa: E402
from repro.models import model as model_lib  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), n_layers=2, d_model=128, vocab=512,
                  seq=args.prompt_len)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = model_lib.init_params(cfg, jax.random.key(0))
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    out_tokens = [np.asarray(tok)[:, 0]]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        step = {"token": tok,
                "cache_pos": jnp.asarray(args.prompt_len + i, jnp.int32)}
        logits, caches = decode(params, step, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} prefill({args.prompt_len} tok x "
          f"{args.batch}): {t_prefill * 1e3:.1f} ms "
          f"(incl. compile)")
    print(f"decode: {args.gen - 1} steps, "
          f"{t_decode / max(args.gen - 1, 1) * 1e3:.2f} ms/token "
          f"(batch {args.batch})")
    print(f"sample continuation (seq 0): {gen[0][:16].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)
    print("OK")


if __name__ == "__main__":
    main()
